//! Event-driven cloud-side connection reactor: **one thread** owns every
//! accepted socket, multiplexing thousands of edge links where the old
//! transport burned a blocked OS thread per connection.
//!
//! Sans-I/O layering: the reactor does the I/O and the *scheduling of*
//! I/O, while all framing lives in [`crate::net::codec::FrameCodec`] and
//! all message semantics in [`crate::coordinator::protocol`].  Per
//! readiness event the reactor reads a chunk, feeds the connection's
//! codec, and routes every completed frame:
//!
//! * `Hello` — pins the connection to a device/session (upload channels
//!   additionally reset the device, exactly like the old per-connection
//!   thread did) and acks;
//! * `UploadHidden` — decoded through the zero-copy
//!   [`Message::decode_upload`] path and routed to the owning worker;
//! * `InferRequest` — routed with a [`Reply`] that posts a completion
//!   record back to the reactor and wakes its poll loop; the response
//!   frame is queued on the connection's codec and drained as the
//!   socket accepts it;
//! * `EndSession` — routed; anything else is answered with an `Error`
//!   frame and the connection is closed once that frame drains.
//!
//! Flow control (knobs: [`ReactorConfig`]):
//! * **Slow-reader eviction** — a connection whose unflushed write queue
//!   exceeds `write_queue_cap` is closed; one stuck reader cannot grow
//!   server memory without bound.
//! * **Worker backpressure** — when a scheduler worker's queue depth
//!   ([`Router::queue_depth`]) exceeds `worker_queue_cap`, the reactor
//!   stops *reading* from that worker's connections, pushing the
//!   overload into kernel TCP flow control instead of heap memory.
//! * **Connection-closed fencing** — completions for a connection that
//!   has since closed are dropped (connection ids are never reused), so
//!   a response can never be written to a recycled socket.
//! * **Idle reap** — established connections with no bytes read or
//!   written for `idle_timeout_s` are closed: a silently-dead peer (NAT
//!   expiry, powered-off device) releases its `max_conns` slot instead
//!   of holding it until a write fails, and its now-idle cloud session
//!   becomes eligible for the context store's TTL sweep.
//!
//! Readiness comes from `poll(2)`, declared directly against the libc
//! every Rust binary already links (no new dependency); cross-thread
//! wakeups use a socketpair-style self-wake.  On non-unix targets a
//! portable fallback probes nonblocking sockets at a small fixed
//! cadence instead.
//!
//! Shutdown is deterministic: [`Reactor::shutdown`] (or drop) closes
//! every registered socket *before* the reactor thread exits, so once
//! the call returns no connection can still produce a response.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::ReactorConfig;
use crate::coordinator::protocol::{Channel, Message, NO_REQ};
use crate::coordinator::scheduler::{InferOutcome, Reply, Router, SchedMsg, UploadPayload};
use crate::model::manifest::ModelDims;
use crate::net::codec::FrameCodec;

// ---------------------------------------------------------------------------
// readiness primitives
// ---------------------------------------------------------------------------

#[cfg(unix)]
type WakeStream = std::os::unix::net::UnixStream;
#[cfg(not(unix))]
type WakeStream = TcpStream;

/// A connected nonblocking pair: `(write end, read end)` of the reactor's
/// self-wake channel.
#[cfg(unix)]
fn wake_pair() -> io::Result<(WakeStream, WakeStream)> {
    let (a, b) = std::os::unix::net::UnixStream::pair()?;
    a.set_nonblocking(true)?;
    b.set_nonblocking(true)?;
    Ok((a, b))
}

#[cfg(not(unix))]
fn wake_pair() -> io::Result<(WakeStream, WakeStream)> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let a = TcpStream::connect(listener.local_addr()?)?;
    let (b, _) = listener.accept()?;
    a.set_nodelay(true)?;
    a.set_nonblocking(true)?;
    b.set_nonblocking(true)?;
    Ok((a, b))
}

/// Cross-thread wake handle: one byte on the self-wake channel makes the
/// reactor's poll return.  `WouldBlock` means wakes are already pending,
/// which is just as good.
#[derive(Clone)]
struct Waker(Arc<WakeStream>);

impl Waker {
    fn wake(&self) {
        // a full pipe (WouldBlock) means wakes are already pending and a
        // closed one means the reactor is gone: both safe to ignore
        let _ = (&*self.0).write_all(&[1]);
    }
}

/// `poll(2)` via the platform libc that every Rust binary already links
/// — keeps the default build dependency-light (no `libc`/`mio` crate).
#[cfg(unix)]
mod sys {
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;

    #[repr(C)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    // nfds_t is `unsigned long` on linux, `unsigned int` on the BSDs/mac
    #[cfg(any(target_os = "linux", target_os = "android", target_os = "emscripten"))]
    type NFds = std::os::raw::c_ulong;
    #[cfg(not(any(target_os = "linux", target_os = "android", target_os = "emscripten")))]
    type NFds = std::os::raw::c_uint;

    extern "C" {
        #[link_name = "poll"]
        fn poll_raw(fds: *mut PollFd, nfds: NFds, timeout_ms: c_int) -> c_int;
    }

    /// Block until a registered fd is ready or `timeout_ms` passes
    /// (`-1` = forever).  EINTR retries transparently.
    pub fn poll(fds: &mut [PollFd], timeout_ms: c_int) -> std::io::Result<usize> {
        loop {
            let r = unsafe { poll_raw(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
            if r >= 0 {
                return Ok(r as usize);
            }
            let e = std::io::Error::last_os_error();
            if e.kind() != std::io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// public handle
// ---------------------------------------------------------------------------

enum Ctl {
    Conn(TcpStream),
    Stats(Sender<ReactorStats>),
    Shutdown,
}

/// A token, eviction notice, or error served by a worker, heading back
/// to the connection that asked for it.
struct Completion {
    conn: u64,
    device: u64,
    req_id: u32,
    pos: u32,
    out: Result<InferOutcome>,
}

/// Cheap cloneable control handle: the acceptor registers connections,
/// anyone may request stats or shutdown.
#[derive(Clone)]
pub struct ReactorHandle {
    ctl: Sender<Ctl>,
    waker: Waker,
}

impl ReactorHandle {
    /// Hand a freshly accepted connection to the reactor.
    pub fn register(&self, stream: TcpStream) -> Result<()> {
        self.ctl.send(Ctl::Conn(stream)).map_err(|_| anyhow!("reactor gone"))?;
        self.waker.wake();
        Ok(())
    }

    /// Snapshot the reactor's counters (blocking round trip).
    pub fn stats(&self) -> Result<ReactorStats> {
        let (tx, rx) = channel();
        self.ctl.send(Ctl::Stats(tx)).map_err(|_| anyhow!("reactor gone"))?;
        self.waker.wake();
        rx.recv().context("reactor stats reply")
    }

    /// Ask the reactor to close every connection and exit (idempotent).
    pub fn shutdown(&self) {
        let _ = self.ctl.send(Ctl::Shutdown);
        self.waker.wake();
    }
}

/// Reactor counters.
#[derive(Debug, Clone, Default)]
pub struct ReactorStats {
    pub conns_opened: u64,
    pub conns_closed: u64,
    /// Accepted connections dropped because `max_conns` was reached.
    pub conns_rejected: u64,
    /// Connections closed because their write queue exceeded the cap.
    pub evicted_slow: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    /// Times a connection's reads were paused by worker backpressure.
    pub read_pauses: u64,
    /// Connections closed for never completing their handshake.
    pub hello_timeouts: u64,
    /// Established connections closed for exceeding the idle timeout
    /// (no bytes read or written) — silently-dead NAT peers.
    pub idle_timeouts: u64,
    /// Connections currently registered (gauge, set on snapshot).
    pub open_conns: usize,
}

/// The reactor thread plus its control handle.
pub struct Reactor {
    handle: ReactorHandle,
    thread: Option<JoinHandle<ReactorStats>>,
}

impl Reactor {
    /// Spawn the reactor thread.  `router` is where decoded work goes;
    /// `dims` validates upload payload shapes (same check the old
    /// connection threads did).
    pub fn spawn(router: Router, dims: ModelDims, cfg: ReactorConfig) -> Result<Reactor> {
        let (ctl_tx, ctl_rx) = channel();
        let (wake_tx, wake_rx) = wake_pair().context("reactor wake channel")?;
        let waker = Waker(Arc::new(wake_tx));
        let handle = ReactorHandle { ctl: ctl_tx, waker: waker.clone() };
        let (comp_tx, comp_rx) = channel();
        let thread = std::thread::Builder::new().name("cloud-reactor".into()).spawn(move || {
            Loop {
                router,
                dims,
                cfg,
                wake_rx,
                ctl_rx,
                comp_tx,
                comp_rx,
                waker,
                conns: HashMap::new(),
                next_id: 1,
                scratch: vec![0u8; 64 * 1024],
                stats: ReactorStats::default(),
                pending_hellos: 0,
                paused_conns: false,
                shutdown: false,
            }
            .run()
        })?;
        Ok(Reactor { handle, thread: Some(thread) })
    }

    pub fn handle(&self) -> ReactorHandle {
        self.handle.clone()
    }

    /// Close every connection, stop the thread, return final counters.
    pub fn shutdown(mut self) -> ReactorStats {
        self.handle.shutdown();
        self.thread.take().map(|t| t.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.handle.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// the loop
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
enum ConnState {
    /// Handshake pending: the first frame must be a `Hello`.
    AwaitingHello,
    Active { device: u64, session: u64, channel: Channel },
}

struct Conn {
    id: u64,
    stream: TcpStream,
    codec: FrameCodec,
    state: ConnState,
    /// Registration time — bounds how long a handshake may stay pending.
    opened: Instant,
    /// Last successful byte read from or written to the peer — the
    /// established-connection idle clock
    /// ([`ReactorConfig::idle_timeout_s`]).
    last_activity: Instant,
    /// Reads paused by worker backpressure.
    paused: bool,
    /// Close as soon as the write queue drains (protocol error sent).
    closing: bool,
}

#[derive(Debug, Clone, Copy, Default)]
struct Ready {
    readable: bool,
    writable: bool,
}

struct Loop {
    router: Router,
    dims: ModelDims,
    cfg: ReactorConfig,
    wake_rx: WakeStream,
    ctl_rx: Receiver<Ctl>,
    comp_tx: Sender<Completion>,
    comp_rx: Receiver<Completion>,
    waker: Waker,
    conns: HashMap<u64, Conn>,
    next_id: u64,
    scratch: Vec<u8>,
    stats: ReactorStats,
    /// Connections still awaiting their Hello — gates the reap scan and
    /// the bounded poll timeout (maintained at register / handshake /
    /// close).
    pending_hellos: usize,
    /// Whether any connection was left paused by the last backpressure
    /// sweep — lets the sweep early-exit in the common unloaded case.
    paused_conns: bool,
    shutdown: bool,
}

impl Loop {
    fn run(mut self) -> ReactorStats {
        loop {
            // channels first, poll second: a sender that raced past our
            // drain has also written a wake byte we have not read yet,
            // so the poll below cannot sleep through it
            self.drain_ctl();
            if self.shutdown {
                break;
            }
            self.drain_completions();
            self.refresh_pauses();
            self.reap_stale_handshakes();
            self.reap_idle_conns();
            let (wake, ready) = self.poll_ready();
            if wake {
                self.drain_wake();
            }
            for (id, r) in ready {
                if r.readable {
                    self.on_readable(id);
                }
                if r.writable {
                    self.on_writable(id);
                }
            }
        }
        // deterministic teardown: every socket is closed before the
        // thread exits, so joining the reactor proves no connection can
        // still produce a response
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            self.close_conn(id, "server shutdown");
        }
        self.stats.open_conns = 0;
        self.stats
    }

    // -- control + completion channels --------------------------------------

    fn drain_ctl(&mut self) {
        while let Ok(ctl) = self.ctl_rx.try_recv() {
            match ctl {
                Ctl::Conn(stream) => {
                    if self.conns.len() >= self.cfg.max_conns {
                        self.stats.conns_rejected += 1;
                        log::warn!(
                            "reactor at max_conns={}; dropping new connection",
                            self.cfg.max_conns
                        );
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() || stream.set_nodelay(true).is_err()
                    {
                        self.stats.conns_rejected += 1;
                        continue;
                    }
                    let id = self.next_id;
                    self.next_id += 1; // ids never reused: stale completions cannot alias
                    let now = Instant::now();
                    self.conns.insert(
                        id,
                        Conn {
                            id,
                            stream,
                            codec: FrameCodec::new(),
                            state: ConnState::AwaitingHello,
                            opened: now,
                            last_activity: now,
                            paused: false,
                            closing: false,
                        },
                    );
                    self.stats.conns_opened += 1;
                    self.pending_hellos += 1;
                }
                Ctl::Stats(reply) => {
                    let mut s = self.stats.clone();
                    s.open_conns = self.conns.len();
                    let _ = reply.send(s);
                }
                Ctl::Shutdown => self.shutdown = true,
            }
        }
    }

    fn drain_completions(&mut self) {
        while let Ok(done) = self.comp_rx.try_recv() {
            if !self.conns.contains_key(&done.conn) {
                // connection-closed fencing: the socket is gone (peer
                // closed, evicted, or reset); ids are never reused, so
                // the response is dropped instead of misdelivered
                continue;
            }
            let frame = match done.out {
                Ok(InferOutcome::Token(t)) => Message::TokenResponse {
                    req_id: done.req_id,
                    pos: done.pos,
                    token: t.token,
                    conf: t.conf,
                    compute_s: t.compute_s as f32,
                }
                .encode(),
                // context-store eviction: the edge replays its history
                // from position 0 and re-issues the request
                Ok(InferOutcome::Evicted) => Message::SessionEvicted {
                    device_id: done.device,
                    req_id: done.req_id,
                    pos: done.pos,
                }
                .encode(),
                Err(e) => Message::Error {
                    req_id: done.req_id,
                    pos: done.pos,
                    msg: format!("{e:#}"),
                }
                .encode(),
            };
            self.enqueue_and_flush(done.conn, &frame);
        }
    }

    fn drain_wake(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.wake_rx.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock: fully drained
            }
        }
    }

    /// Close connections that never completed their handshake.  Without
    /// this, sockets that connect and go silent would hold registration
    /// slots forever — and with `max_conns` admission, enough of them
    /// would lock every future device out.
    fn reap_stale_handshakes(&mut self) {
        if self.pending_hellos == 0 {
            return; // the scan only runs while handshakes are pending
        }
        let timeout = Duration::from_secs_f64(self.cfg.hello_timeout_s.max(0.001));
        let now = Instant::now();
        let stale: Vec<u64> = self
            .conns
            .values()
            .filter(|c| {
                matches!(c.state, ConnState::AwaitingHello)
                    && now.duration_since(c.opened) > timeout
            })
            .map(|c| c.id)
            .collect();
        for id in stale {
            self.stats.hello_timeouts += 1;
            self.close_conn(id, "no Hello within the handshake timeout");
        }
    }

    /// Close *established* connections whose peer has gone silent: no
    /// byte read from or written to them for `idle_timeout_s`.  A NAT
    /// table that expired, or a device that powered off mid-session,
    /// leaves a socket that never errors until written to — without this
    /// reap it holds a `max_conns` slot forever.  Reaping the connection
    /// also idles the device's cloud session, which the context store's
    /// TTL sweep then releases.
    fn reap_idle_conns(&mut self) {
        if self.cfg.idle_timeout_s <= 0.0 || self.conns.is_empty() {
            return;
        }
        let timeout = Duration::from_secs_f64(self.cfg.idle_timeout_s);
        let now = Instant::now();
        let stale: Vec<u64> = self
            .conns
            .values()
            .filter(|c| {
                // a backpressure-paused conn is not idle: the reactor is
                // refusing to read it, so its peer may be sending into
                // the kernel buffer this whole time
                !c.paused
                    && matches!(c.state, ConnState::Active { .. })
                    && now.saturating_duration_since(c.last_activity) > timeout
            })
            .map(|c| c.id)
            .collect();
        for id in stale {
            self.stats.idle_timeouts += 1;
            self.close_conn(id, "idle timeout (no reads or writes from peer)");
        }
    }

    /// Re-evaluate worker backpressure for every active connection.
    /// Overload is a per-worker property, so the queue depths are read
    /// once per worker, and the per-connection sweep runs only when
    /// there is something to pause or unpause.
    fn refresh_pauses(&mut self) {
        let cap = self.cfg.worker_queue_cap;
        let overloaded: Vec<bool> =
            (0..self.router.workers()).map(|w| self.router.queue_depth(w) > cap).collect();
        if !self.paused_conns && !overloaded.iter().any(|&o| o) {
            return; // nothing paused, nothing to pause
        }
        let mut still_paused = false;
        for c in self.conns.values_mut() {
            if let ConnState::Active { device, .. } = c.state {
                let o = overloaded[self.router.worker_for(device)];
                if o && !c.paused {
                    self.stats.read_pauses += 1;
                }
                if !o && c.paused {
                    // resuming reads: the pause was the reactor's doing,
                    // so the quiet stretch must not count toward the
                    // peer's idle timeout
                    c.last_activity = Instant::now();
                }
                c.paused = o;
                still_paused |= o;
            }
        }
        self.paused_conns = still_paused;
    }

    // -- readiness ----------------------------------------------------------

    #[cfg(unix)]
    fn poll_ready(&mut self) -> (bool, Vec<(u64, Ready)>) {
        use std::os::unix::io::AsRawFd;
        let mut fds = Vec::with_capacity(self.conns.len() + 1);
        fds.push(sys::PollFd { fd: self.wake_rx.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        let mut ids = Vec::with_capacity(self.conns.len());
        let mut any_paused = false;
        let any_handshaking = self.pending_hellos > 0;
        let idle_timeout = (self.cfg.idle_timeout_s > 0.0)
            .then(|| Duration::from_secs_f64(self.cfg.idle_timeout_s));
        let mut oldest_activity: Option<Instant> = None;
        for c in self.conns.values() {
            let mut ev = 0i16;
            if !c.paused && !c.closing {
                ev |= sys::POLLIN;
            }
            if c.codec.pending_out() > 0 {
                ev |= sys::POLLOUT;
            }
            any_paused |= c.paused;
            if idle_timeout.is_some() && !c.paused && matches!(c.state, ConnState::Active { .. })
            {
                oldest_activity =
                    Some(oldest_activity.map_or(c.last_activity, |o| o.min(c.last_activity)));
            }
            // fds with events == 0 still report ERR/HUP, so a paused
            // connection whose peer vanished is reaped promptly
            fds.push(sys::PollFd { fd: c.stream.as_raw_fd(), events: ev, revents: 0 });
            ids.push(c.id);
        }
        // workers do not wake the reactor when they catch up, so paused
        // reads re-check the queue depth at a short cadence; pending
        // handshakes and armed idle timeouts need bounded sleeps so a
        // silent socket still hits its reap deadline
        let timeout_ms = if any_paused {
            2
        } else {
            let mut t: i64 = if any_handshaking { 500 } else { -1 };
            if let (Some(idle), Some(oldest)) = (idle_timeout, oldest_activity) {
                let deadline = oldest + idle;
                let ms = deadline.saturating_duration_since(Instant::now()).as_millis() as i64;
                // floor keeps a just-missed deadline from busy-spinning;
                // cap keeps the pollfd rebuild cadence reasonable
                let ms = (ms + 1).clamp(10, 60_000);
                t = if t < 0 { ms } else { t.min(ms) };
            }
            t as std::os::raw::c_int
        };
        if let Err(e) = sys::poll(&mut fds, timeout_ms) {
            log::warn!("reactor poll failed: {e}");
            std::thread::sleep(Duration::from_millis(1));
            return (true, Vec::new());
        }
        let wake = fds[0].revents != 0;
        let err_mask = sys::POLLERR | sys::POLLHUP | sys::POLLNVAL;
        let ready = ids
            .into_iter()
            .zip(fds.iter().skip(1))
            .filter(|(_, f)| f.revents != 0)
            .map(|(id, f)| {
                (
                    id,
                    Ready {
                        // ERR/HUP surface through a read() so the real
                        // error (or EOF) is observed and the conn reaped
                        readable: f.revents & (sys::POLLIN | err_mask) != 0,
                        writable: f.revents & sys::POLLOUT != 0,
                    },
                )
            })
            .collect();
        (wake, ready)
    }

    /// Portable fallback without `poll(2)`: probe nonblocking sockets at
    /// a small fixed cadence (idle probes cost one `WouldBlock` read).
    #[cfg(not(unix))]
    fn poll_ready(&mut self) -> (bool, Vec<(u64, Ready)>) {
        std::thread::sleep(Duration::from_millis(1));
        let ready = self
            .conns
            .values()
            .map(|c| {
                (
                    c.id,
                    Ready {
                        readable: !c.paused && !c.closing,
                        writable: c.codec.pending_out() > 0,
                    },
                )
            })
            .collect();
        (true, ready)
    }

    // -- per-connection I/O --------------------------------------------------

    fn on_readable(&mut self, id: u64) {
        let mut scratch = std::mem::take(&mut self.scratch);
        let (frames, close) = match self.conns.get_mut(&id) {
            Some(c) => read_frames(c, &mut scratch),
            None => {
                self.scratch = scratch;
                return;
            }
        };
        self.scratch = scratch;
        // frames completed before any poison/EOF are still routed
        for frame in frames {
            // a mid-batch protocol error closes (or marks closing) the
            // conn; later frames are void
            match self.conns.get(&id) {
                Some(c) if !c.closing => {}
                _ => break,
            }
            if let Err(e) = self.on_frame(id, frame) {
                self.close_conn(id, &format!("{e:#}"));
                break;
            }
        }
        if let Some(reason) = close {
            self.close_conn(id, &reason); // idempotent if already closed
        }
    }

    fn on_writable(&mut self, id: u64) {
        let mut fail: Option<String> = None;
        let mut drained_closing = false;
        if let Some(c) = self.conns.get_mut(&id) {
            match flush_conn(c) {
                Err(e) => fail = Some(format!("write failed: {e}")),
                Ok(()) => drained_closing = c.closing && c.codec.pending_out() == 0,
            }
        }
        if let Some(reason) = fail {
            self.close_conn(id, &reason);
        } else if drained_closing {
            self.close_conn(id, "closed after protocol error");
        }
    }

    /// Handle one decoded frame.  `Err` means "close this connection".
    fn on_frame(&mut self, id: u64, frame: Vec<u8>) -> Result<()> {
        self.stats.frames_in += 1;
        let Some(state) = self.conns.get(&id).map(|c| c.state) else { return Ok(()) };
        match state {
            ConnState::AwaitingHello => {
                let (device_id, session, channel) = match Message::decode(&frame)? {
                    Message::Hello { device_id, session, channel } => {
                        (device_id, session, channel)
                    }
                    other => anyhow::bail!("expected Hello, got {other:?}"),
                };
                if channel == Channel::Upload {
                    // fresh upload channel = fresh client session: reset
                    // the device and pin it to this session, queued ahead
                    // of everything the session will send (see the
                    // coordinator::cloud docs)
                    self.router
                        .send(device_id, SchedMsg::Reset { device: device_id, session })
                        .context("scheduler gone")?;
                }
                if let Some(c) = self.conns.get_mut(&id) {
                    c.state = ConnState::Active { device: device_id, session, channel };
                    self.pending_hellos = self.pending_hellos.saturating_sub(1);
                }
                log::debug!("device {device_id} opened {channel:?} channel (session {session:x})");
                self.enqueue_and_flush(id, &Message::Ack.encode());
                Ok(())
            }
            ConnState::Active { session, channel, .. } => {
                // zero-copy fast path for the dominant per-token frame
                // (payload borrowed from the frame buffer); the packed
                // bytes are forwarded as-is and the f16→f32 unpack runs
                // on the OWNING WORKER, so ingest CPU scales with the
                // pool instead of serializing on this one thread
                if let Some(v) = Message::decode_upload(&frame)? {
                    anyhow::ensure!(
                        v.payload.len() % (self.dims.d_model * v.precision.bytes_per_elem()) == 0,
                        "ragged upload"
                    );
                    return self
                        .router
                        .send(
                            v.device_id,
                            SchedMsg::Upload {
                                device: v.device_id,
                                session,
                                req_id: v.req_id,
                                start_pos: v.start_pos,
                                prompt_len: v.prompt_len,
                                payload: UploadPayload::Packed {
                                    bytes: v.payload.to_vec(),
                                    precision: v.precision,
                                },
                            },
                        )
                        .context("scheduler gone");
                }
                match Message::decode(&frame)? {
                    Message::InferRequest { device_id, req_id, pos, prompt_len, deadline_ms } => {
                        let deadline = (deadline_ms > 0)
                            .then(|| Instant::now() + Duration::from_millis(deadline_ms as u64));
                        let comp = self.comp_tx.clone();
                        let waker = self.waker.clone();
                        let conn = id;
                        let reply = Reply::new(move |out| {
                            let _ =
                                comp.send(Completion { conn, device: device_id, req_id, pos, out });
                            waker.wake();
                        });
                        self.router
                            .send(
                                device_id,
                                SchedMsg::Infer {
                                    device: device_id,
                                    session,
                                    req_id,
                                    pos,
                                    prompt_len,
                                    deadline,
                                    reply,
                                },
                            )
                            .context("scheduler gone")
                    }
                    Message::EndSession { device_id, req_id } => self
                        .router
                        .send(device_id, SchedMsg::End { device: device_id, session, req_id })
                        .context("scheduler gone"),
                    other => {
                        let msg = format!("unexpected message on {channel:?} channel: {other:?}");
                        log::debug!("reactor: {msg}");
                        self.enqueue_and_flush(
                            id,
                            &Message::Error { req_id: NO_REQ, pos: NO_REQ, msg }.encode(),
                        );
                        let drained = self
                            .conns
                            .get_mut(&id)
                            .map(|c| {
                                c.closing = true;
                                c.codec.pending_out() == 0
                            })
                            .unwrap_or(false);
                        if drained {
                            self.close_conn(id, "closed after protocol error");
                        }
                        Ok(())
                    }
                }
            }
        }
    }

    /// Queue one frame on `id`'s codec, flush what the socket accepts
    /// now, and evict the connection if its backlog exceeds the cap.
    fn enqueue_and_flush(&mut self, id: u64, payload: &[u8]) {
        let mut fail: Option<String> = None;
        let mut evict = false;
        if let Some(c) = self.conns.get_mut(&id) {
            match c.codec.enqueue_frame(payload) {
                Err(e) => fail = Some(format!("{e:#}")),
                Ok(()) => {
                    self.stats.frames_out += 1;
                    match flush_conn(c) {
                        Err(e) => fail = Some(format!("write failed: {e}")),
                        Ok(()) => evict = c.codec.pending_out() > self.cfg.write_queue_cap,
                    }
                }
            }
        }
        if let Some(reason) = fail {
            self.close_conn(id, &reason);
        } else if evict {
            self.stats.evicted_slow += 1;
            self.close_conn(id, "write queue over cap (slow reader evicted)");
        }
    }

    fn close_conn(&mut self, id: u64, reason: &str) {
        if let Some(c) = self.conns.remove(&id) {
            if matches!(c.state, ConnState::AwaitingHello) {
                self.pending_hellos = self.pending_hellos.saturating_sub(1);
            }
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
            self.stats.conns_closed += 1;
            log::debug!("reactor: connection {id} closed: {reason}");
        }
    }
}

/// One nonblocking read, fed through the connection's codec.  Returns
/// every frame the read completed plus an optional close reason — valid
/// frames parsed before a poisoned one (or EOF) are still delivered, so
/// an upload in the same TCP segment as the corruption is not lost.
fn read_frames(c: &mut Conn, scratch: &mut [u8]) -> (Vec<Vec<u8>>, Option<String>) {
    match c.stream.read(scratch) {
        Ok(0) => (Vec::new(), Some("peer closed".into())),
        Ok(n) => {
            c.last_activity = Instant::now();
            let mut frames = Vec::new();
            // feed_all parses whole frames straight from the read chunk
            // (no staging copy through the codec buffer on bulk ingest)
            match c.codec.feed_all(&scratch[..n], &mut frames) {
                Ok(()) => (frames, None),
                Err(e) => (frames, Some(format!("bad frame: {e:#}"))),
            }
        }
        Err(e)
            if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted) =>
        {
            (Vec::new(), None)
        }
        Err(e) => (Vec::new(), Some(format!("read failed: {e}"))),
    }
}

/// Write as much of the connection's queue as the socket accepts now.
fn flush_conn(c: &mut Conn) -> io::Result<()> {
    while c.codec.pending_out() > 0 {
        match c.stream.write(c.codec.writable_bytes()) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "write returned 0")),
            Ok(n) => {
                c.last_activity = Instant::now();
                c.codec.consume_written(n);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}
