//! Event-driven cloud-side connection reactor **fleet**: `shards`
//! threads (default `min(4, cores)`, [`crate::config::SHARDS_ENV`]
//! override) share every cloud-side socket, where the old transport
//! burned a blocked OS thread per connection.  One shard is the PR-5
//! single reactor, unchanged in spirit; the fleet exists because one
//! event loop saturates somewhere around ~100k connections, and the
//! cloud's north star is millions.
//!
//! Sharding contract — **zero cross-shard locking on the hot path**:
//!
//! * every shard owns its own [`EventSet`] (epoll on Linux, poll
//!   fallback), its own connection table, write queues, codec scratch,
//!   stats, and completion channel.  Admission, reads, backpressure
//!   pause/resume, and completion fan-out never touch another shard's
//!   state — the only shared objects are the scheduler's [`Router`]
//!   (lock-free channel sends + atomic depth gauges) and the listener
//!   arrangement below;
//! * **accepting** is per-shard: on Linux, when the server binds its
//!   own listeners ([`crate::net::listener::bind_shard_listeners`]),
//!   each shard owns a private `SO_REUSEPORT` listener and the kernel's
//!   4-tuple hash spreads connections across the fleet with no shared
//!   accept queue at all.  A caller-provided listener (or a platform
//!   without reuseport) degrades to a shared accept queue: every shard
//!   registers a dup of the same listener fd and races `accept`
//!   (losers see `WouldBlock`).  Admission uses
//!   `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)` on Linux — no per-accept
//!   fcntl round trips — with the portable `accept` + `set_nonblocking`
//!   pair elsewhere;
//! * **connection ids are shard-tagged**: the top [`SHARD_BITS`] bits
//!   of a conn id name the owning shard, the low bits a per-shard
//!   counter that never reuses values.  Completions therefore resolve
//!   to exactly one shard's completion channel and waker
//!   ([`ReactorHandle`] fans control out; [`Reply`] sinks created by a
//!   shard post back to that same shard), and the dead-conn fencing of
//!   the single-reactor design carries over: a completion for a closed
//!   conn on shard A is dropped by shard A and *cannot* alias a live
//!   conn on shard B, because B's table only ever holds B-tagged ids;
//! * `max_conns` admission becomes an even per-shard share
//!   (`max_conns / shards`, floor 1 — the same split as the context
//!   store's per-worker budget), so no shard consults any global count.
//!
//! Everything below the fleet layer is the single-reactor design:
//!
//! Sans-I/O layering: the reactor does the I/O and the *scheduling of*
//! I/O, while all framing lives in [`crate::net::codec::FrameCodec`],
//! all message semantics in [`crate::coordinator::protocol`], and all
//! readiness in [`crate::net::event::EventSet`].  Per readiness event
//! the shard reads until `WouldBlock` (the edge-triggered contract)
//! or a per-event budget (`READS_PER_EVENT`; the event is re-armed so
//! one firehose peer cannot starve the others), feeds the connection's
//! codec — large upload bodies land straight in their final frame
//! buffer via the codec's single-copy
//! [`read_slot`](crate::net::codec::FrameCodec::read_slot) path — and
//! routes every completed frame:
//!
//! * `Hello` — pins the connection to a device/session (upload channels
//!   additionally reset the device, exactly like the old per-connection
//!   thread did) and acks;
//! * `UploadHidden` — decoded through the zero-copy
//!   [`Message::decode_upload`] path and routed to the owning worker;
//! * `InferRequest` — routed with a [`Reply`] that posts a completion
//!   record back to the owning shard and wakes its event loop; the
//!   response frame is queued on the connection's codec and drained as
//!   the socket accepts it;
//! * `EndSession` — routed; anything else is answered with an `Error`
//!   frame and the connection is closed once that frame drains.
//!
//! Flow control (knobs: [`ReactorConfig`]):
//! * **Slow-reader eviction** — a connection whose unflushed write queue
//!   exceeds `write_queue_cap` is closed; one stuck reader cannot grow
//!   server memory without bound.
//! * **Worker backpressure** — when a scheduler worker's queue depth
//!   ([`Router::queue_depth`]) exceeds `worker_queue_cap`, each shard
//!   stops *reading* from that worker's connections it owns, pushing
//!   the overload into kernel TCP flow control instead of heap memory.
//!   Pausing and resuming are O(1) interest changes on the shard's own
//!   event set, and re-arming re-delivers the edge for bytes that
//!   arrived mid-pause, so resumption cannot stall.
//! * **Connection-closed fencing** — completions for a connection that
//!   has since closed are dropped (connection ids are never reused, and
//!   carry their shard), so a response can never be written to a
//!   recycled — or foreign — socket.
//! * **Idle reap** — established connections with no bytes read or
//!   written for `idle_timeout_s` are closed: a silently-dead peer (NAT
//!   expiry, powered-off device) releases its admission slot instead
//!   of holding it until a write fails, and its now-idle cloud session
//!   becomes eligible for the context store's TTL sweep.
//!
//! Per-wake cost: with no pauses, pending handshakes, or armed idle
//! timers, a shard's wake touches only its channels (`try_recv` until
//! empty), one queue-depth read per *worker*, and the connections that
//! are actually ready — on the epoll backend that is independent of how
//! many sockets the shard holds ([`ReactorStats::wakes`] /
//! [`ReactorStats::events_seen`] make the claim measurable, per shard
//! and aggregated).  The `poll(2)` backend keeps the portable
//! O(conns-per-shard) behaviour — itself a 1/shards improvement.
//! Cross-thread wakeups use a socketpair-style self-wake registered in
//! each shard's event set.
//!
//! Shutdown is deterministic: [`Reactor::shutdown`] (or drop) closes
//! every registered socket on every shard *before* the fleet's threads
//! exit, so once the call returns no connection can still produce a
//! response.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::config::ReactorConfig;
use crate::coordinator::protocol::{Channel, Message, NO_REQ};
use crate::coordinator::scheduler::{InferOutcome, Reply, Router, SchedMsg, UploadPayload};
use crate::metrics::{LatencyHist, MetricsRegistry};
use crate::model::manifest::ModelDims;
use crate::net::codec::FrameCodec;
use crate::net::event::{Event, EventSet, Interest, SourceFd, Token};
use crate::net::fault::ReactorFault;
use crate::net::listener::{self, MODE_NONE};
use crate::trace::{Ev, TraceSink};

// ---------------------------------------------------------------------------
// readiness primitives
// ---------------------------------------------------------------------------

#[cfg(unix)]
type WakeStream = std::os::unix::net::UnixStream;
#[cfg(not(unix))]
type WakeStream = TcpStream;

/// A connected nonblocking pair: `(write end, read end)` of a shard's
/// self-wake channel.
#[cfg(unix)]
fn wake_pair() -> io::Result<(WakeStream, WakeStream)> {
    let (a, b) = std::os::unix::net::UnixStream::pair()?;
    a.set_nonblocking(true)?;
    b.set_nonblocking(true)?;
    Ok((a, b))
}

#[cfg(not(unix))]
fn wake_pair() -> io::Result<(WakeStream, WakeStream)> {
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let a = TcpStream::connect(listener.local_addr()?)?;
    let (b, _) = listener.accept()?;
    a.set_nodelay(true)?;
    a.set_nonblocking(true)?;
    b.set_nonblocking(true)?;
    Ok((a, b))
}

/// The event-set key of a shard's self-wake channel.
const WAKE_TOKEN: Token = 0;
/// The event-set key of a shard's listener fd (shard-local conn ids
/// start at 1 and never reach the all-ones pattern).
const LISTEN_TOKEN: Token = u64::MAX;

/// Bits of a connection id reserved for the owning shard's index.
/// `config::MAX_REACTOR_SHARDS` keeps real fleets far below 2^8, and a
/// 56-bit per-shard counter never wraps in practice.
const SHARD_BITS: u32 = 8;
const SHARD_SHIFT: u32 = 64 - SHARD_BITS;

/// Tag a shard-local connection counter with its owning shard.
fn tag_conn(shard: usize, local: u64) -> u64 {
    debug_assert!(local > 0 && local < (1u64 << SHARD_SHIFT));
    ((shard as u64) << SHARD_SHIFT) | local
}

/// The shard that owns (and alone may resolve) connection id `conn`.
fn shard_of(conn: u64) -> usize {
    (conn >> SHARD_SHIFT) as usize
}

/// The shard-local counter part of a connection id.  Trace events carry
/// `shard` and this 56-bit local id as separate fields: the combined
/// tagged id of a high shard exceeds 2^53 and would lose precision in a
/// JSON double.
fn local_of(conn: u64) -> u64 {
    conn & ((1u64 << SHARD_SHIFT) - 1)
}

#[cfg(unix)]
fn raw_fd<T: std::os::unix::io::AsRawFd>(t: &T) -> SourceFd {
    t.as_raw_fd()
}
#[cfg(not(unix))]
fn raw_fd<T>(_t: &T) -> SourceFd {
    0 // the probe backend keys on tokens alone
}

/// Cross-thread wake handle: one byte on a shard's self-wake channel
/// makes that shard's wait return.  `WouldBlock` means wakes are
/// already pending, which is just as good.
#[derive(Clone)]
struct Waker(Arc<WakeStream>);

impl Waker {
    fn wake(&self) {
        // a full pipe (WouldBlock) means wakes are already pending and a
        // closed one means the shard is gone: both safe to ignore
        let _ = (&*self.0).write_all(&[1]);
    }
}

// ---------------------------------------------------------------------------
// public handle
// ---------------------------------------------------------------------------

enum Ctl {
    Conn(TcpStream),
    Stats(Sender<ReactorStats>),
    Shutdown,
}

/// A token, eviction notice, or error served by a worker, heading back
/// to the connection that asked for it.  Created by a shard, posted to
/// that same shard's completion channel: `conn` is shard-tagged, so the
/// record can never be resolved against another shard's table.
struct Completion {
    conn: u64,
    device: u64,
    req_id: u32,
    pos: u32,
    out: Result<InferOutcome>,
}

/// One shard's control surface: its command channel plus its waker.
#[derive(Clone)]
struct ShardHandle {
    ctl: Sender<Ctl>,
    waker: Waker,
}

impl ShardHandle {
    fn send(&self, ctl: Ctl) -> Result<()> {
        self.ctl.send(ctl).map_err(|_| anyhow!("reactor shard gone"))?;
        self.waker.wake();
        Ok(())
    }
}

/// Cheap cloneable control handle over the whole fleet: tests and
/// in-process servers may register connections directly (spread
/// round-robin across shards); anyone may request stats or shutdown.
/// Control fan-out resolves to the owning shard's channel + waker —
/// there is no fleet-global lock.
#[derive(Clone)]
pub struct ReactorHandle {
    shards: Vec<ShardHandle>,
    /// Round-robin cursor for [`ReactorHandle::register`].
    next: Arc<AtomicUsize>,
}

impl ReactorHandle {
    /// Shards in the fleet.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Hand an externally accepted connection to the fleet (round-robin
    /// across shards — deterministic: the `i`-th registration lands on
    /// shard `i % shards`).  The serve path does not need this (each
    /// shard owns its accept path); it remains for tests and embedding.
    pub fn register(&self, stream: TcpStream) -> Result<()> {
        let shard = self.next.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.shards[shard].send(Ctl::Conn(stream))
    }

    /// Snapshot every shard's counters, in shard order.  All shards are
    /// asked first and awaited second, so the round trips overlap.
    pub fn shard_stats(&self) -> Result<Vec<ReactorStats>> {
        let mut pending = Vec::with_capacity(self.shards.len());
        for shard in &self.shards {
            let (tx, rx) = channel();
            shard.send(Ctl::Stats(tx))?;
            pending.push(rx);
        }
        pending.into_iter().map(|rx| rx.recv().context("reactor shard stats reply")).collect()
    }

    /// Snapshot the fleet's counters, summed across shards
    /// ([`ReactorStats::merge`]); per-shard resolution is a
    /// [`ReactorHandle::shard_stats`] away.
    pub fn stats(&self) -> Result<ReactorStats> {
        let mut total = ReactorStats::default();
        for s in self.shard_stats()? {
            total.merge(&s);
        }
        Ok(total)
    }

    /// Ask every shard to close its connections and exit (idempotent).
    pub fn shutdown(&self) {
        for shard in &self.shards {
            let _ = shard.ctl.send(Ctl::Shutdown);
            shard.waker.wake();
        }
    }
}

/// One shard's counters — or, after [`ReactorStats::merge`], the
/// fleet's aggregate.  The soak test prints the per-shard accept
/// histogram from the un-merged vector, which is how shard imbalance
/// (a skewed reuseport hash, a hot register path) stays observable.
#[derive(Debug, Clone, Default)]
pub struct ReactorStats {
    pub conns_opened: u64,
    pub conns_closed: u64,
    /// Accepted connections dropped because the shard's `max_conns`
    /// share was reached.
    pub conns_rejected: u64,
    /// Connections closed because their write queue exceeded the cap.
    pub evicted_slow: u64,
    pub frames_in: u64,
    pub frames_out: u64,
    /// Times a connection's reads were paused by worker backpressure.
    pub read_pauses: u64,
    /// Connections closed for never completing their handshake.
    pub hello_timeouts: u64,
    /// Established connections closed for exceeding the idle timeout
    /// (no bytes read or written) — silently-dead NAT peers.
    pub idle_timeouts: u64,
    /// Connections severed by the deterministic fault hook
    /// ([`ReactorFault`], `CE_FAULT`).  Always 0 in production.
    pub faults_injected: u64,
    /// Event-loop iterations (one `EventSet::wait` return each).
    pub wakes: u64,
    /// Sockets accepted in-loop from the shard's listener fd (includes
    /// ones later rejected by admission).
    pub accepts: u64,
    /// Readiness events dispatched across all wakes; `events_seen /
    /// wakes` is the measured per-wake fan-out the epoll backend keeps
    /// independent of connection count.
    pub events_seen: u64,
    /// Which readiness backend the loop runs on ("epoll", "poll", or
    /// the non-unix "probe").
    pub backend: &'static str,
    /// How this shard's accept path was provisioned ("reuseport",
    /// "shared", "single", or "none" — see [`crate::net::listener`]).
    pub accept_mode: &'static str,
    /// Connections currently registered (gauge, set on snapshot).
    pub open_conns: usize,
    /// Trace events this shard emitted into the [`TraceSink`] (0 when
    /// recording is off).
    pub trace_events: u64,
    /// Trace events dropped because the sink's bounded queue was full —
    /// the recorder degrades visibly, it never blocks the shard.
    pub trace_dropped: u64,
}

impl ReactorStats {
    /// Fold another shard's counters into this one.  Counters and
    /// gauges sum; maxima take the max; the backend/accept-mode labels
    /// keep the first non-empty value (shards of one fleet share them).
    pub fn merge(&mut self, o: &ReactorStats) {
        self.conns_opened += o.conns_opened;
        self.conns_closed += o.conns_closed;
        self.conns_rejected += o.conns_rejected;
        self.evicted_slow += o.evicted_slow;
        self.frames_in += o.frames_in;
        self.frames_out += o.frames_out;
        self.read_pauses += o.read_pauses;
        self.hello_timeouts += o.hello_timeouts;
        self.idle_timeouts += o.idle_timeouts;
        self.faults_injected += o.faults_injected;
        self.wakes += o.wakes;
        self.accepts += o.accepts;
        self.events_seen += o.events_seen;
        self.open_conns += o.open_conns;
        self.trace_events += o.trace_events;
        self.trace_dropped += o.trace_dropped;
        if self.backend.is_empty() {
            self.backend = o.backend;
        }
        if self.accept_mode.is_empty() {
            self.accept_mode = o.accept_mode;
        }
    }
}

/// The reactor fleet: `shards` event-loop threads plus their fan-out
/// control handle.
pub struct Reactor {
    handle: ReactorHandle,
    threads: Vec<JoinHandle<ReactorStats>>,
}

impl Reactor {
    /// Spawn the fleet from a single optional pre-bound listener.
    /// `router` is where decoded work goes; `dims` validates upload
    /// payload shapes (same check the old connection threads did).
    /// With `listener` set, its accept queue is *shared* across the
    /// shards (dup'd fd — the only arrangement a caller-bound listener
    /// admits); servers that want true per-shard `SO_REUSEPORT`
    /// listeners bind them through
    /// [`crate::net::listener::bind_shard_listeners`] and call
    /// [`Reactor::spawn_fleet`].  With `listener` unset, connections
    /// arrive only via [`ReactorHandle::register`].
    pub fn spawn(
        router: Router,
        dims: ModelDims,
        cfg: ReactorConfig,
        listener: Option<TcpListener>,
    ) -> Result<Reactor> {
        Self::spawn_traced(router, dims, cfg, listener, None)
    }

    /// [`Reactor::spawn`] with a trace recorder: every shard taps its
    /// frame and connection lifecycle events into `sink` (the same sink
    /// the scheduler records into, so the sequence interleaves).
    pub fn spawn_traced(
        router: Router,
        dims: ModelDims,
        cfg: ReactorConfig,
        listener: Option<TcpListener>,
        sink: Option<Arc<TraceSink>>,
    ) -> Result<Reactor> {
        let shards = cfg.resolved_shards();
        let (mode, listeners) = match listener {
            Some(l) => listener::share_listener(l, shards),
            None => (MODE_NONE, (0..shards).map(|_| None).collect()),
        };
        Self::spawn_fleet_traced(router, dims, cfg, listeners, mode, sink)
    }

    /// Spawn one shard per listener slot (`listeners.len()` shards; a
    /// `None` slot is a shard that only serves registered connections).
    /// `accept_mode` labels how the slots were provisioned, for stats.
    pub fn spawn_fleet(
        router: Router,
        dims: ModelDims,
        cfg: ReactorConfig,
        listeners: Vec<Option<TcpListener>>,
        accept_mode: &'static str,
    ) -> Result<Reactor> {
        Self::spawn_fleet_traced(router, dims, cfg, listeners, accept_mode, None)
    }

    /// [`Reactor::spawn_fleet`] with a trace recorder (see
    /// [`Reactor::spawn_traced`]).  Metrics resolve from the
    /// environment (`CE_METRICS`); callers that carry an explicit flag
    /// use [`Reactor::spawn_fleet_full`].
    pub fn spawn_fleet_traced(
        router: Router,
        dims: ModelDims,
        cfg: ReactorConfig,
        listeners: Vec<Option<TcpListener>>,
        accept_mode: &'static str,
        sink: Option<Arc<TraceSink>>,
    ) -> Result<Reactor> {
        let metrics = MetricsRegistry::resolve(false);
        Self::spawn_fleet_full(router, dims, cfg, listeners, accept_mode, sink, metrics)
    }

    /// The full-parameter fleet spawn: trace recorder plus an optional
    /// metrics registry.  With metrics on, every shard registers its
    /// latency histograms, publishes its load cells for the fleet
    /// accept-load report, and serves `GET /metrics` scrapes on its own
    /// listener (no extra thread, no extra port — see
    /// [`Loop::sniff_readable`]).
    pub fn spawn_fleet_full(
        router: Router,
        dims: ModelDims,
        cfg: ReactorConfig,
        listeners: Vec<Option<TcpListener>>,
        accept_mode: &'static str,
        sink: Option<Arc<TraceSink>>,
        metrics: Option<Arc<MetricsRegistry>>,
    ) -> Result<Reactor> {
        let shards = listeners.len();
        ensure!(shards >= 1, "a reactor fleet needs at least one shard");
        ensure!(
            shards <= crate::config::MAX_REACTOR_SHARDS,
            "reactor fleet of {shards} shards exceeds the id-tag cap"
        );
        // the admission bound splits into even per-shard shares (floor
        // 1), exactly like the context store's per-worker budget split:
        // enforcement needs no cross-shard coordination and the shares
        // sum back to (at least) the configured bound
        let mut scfg = cfg;
        scfg.max_conns = (cfg.max_conns / shards).max(1);
        // the fault hook resolves once for the whole fleet (explicit
        // config wins over the CE_FAULT env var), so every shard runs
        // the same deterministic schedule
        let fault = ReactorFault::resolve(cfg.fault);
        if let Some(f) = fault {
            log::warn!("reactor fleet running with injected faults: {f:?}");
        }
        // one load cell per shard, shared by the whole fleet: any shard
        // can render the fleet-wide accept-load report from them while
        // its siblings keep publishing with relaxed stores
        let load: Arc<Vec<ShardLoad>> =
            Arc::new((0..shards).map(|_| ShardLoad::default()).collect());
        let mut shard_handles = Vec::with_capacity(shards);
        let mut threads = Vec::with_capacity(shards);
        for (shard, slot) in listeners.into_iter().enumerate() {
            let (ctl_tx, ctl_rx) = channel();
            let (wake_tx, wake_rx) = wake_pair().context("reactor wake channel")?;
            let events = EventSet::new(cfg.backend).context("reactor readiness backend")?;
            let waker = Waker(Arc::new(wake_tx));
            let (comp_tx, comp_rx) = channel();
            let router = router.clone();
            let dims = dims.clone();
            let loop_waker = waker.clone();
            let sink = sink.clone();
            let metrics =
                metrics.as_ref().map(|reg| ShardMetrics::new(reg.clone(), load.clone(), shard));
            let thread = std::thread::Builder::new()
                .name(format!("cloud-reactor-{shard}"))
                .spawn(move || {
                    Loop {
                        shard,
                        router,
                        dims,
                        cfg: scfg,
                        wake_rx,
                        listener: slot,
                        ctl_rx,
                        comp_tx,
                        comp_rx,
                        waker: loop_waker,
                        events,
                        evbuf: Vec::with_capacity(1024),
                        conns: HashMap::new(),
                        next_local: 1,
                        scratch: vec![0u8; 64 * 1024],
                        stats: ReactorStats { accept_mode, ..ReactorStats::default() },
                        fault,
                        sink,
                        metrics,
                        pending_hellos: 0,
                        paused_conns: false,
                        shutdown: false,
                    }
                    .run()
                })?;
            shard_handles.push(ShardHandle { ctl: ctl_tx, waker });
            threads.push(thread);
        }
        let handle = ReactorHandle { shards: shard_handles, next: Arc::new(AtomicUsize::new(0)) };
        Ok(Reactor { handle, threads })
    }

    pub fn handle(&self) -> ReactorHandle {
        self.handle.clone()
    }

    /// Shards in the fleet.
    pub fn shards(&self) -> usize {
        self.threads.len()
    }

    /// Close every connection on every shard, stop the threads, and
    /// return each shard's final counters (index = shard).
    pub fn shutdown(mut self) -> Vec<ReactorStats> {
        self.handle.shutdown();
        self.threads.drain(..).map(|t| t.join().unwrap_or_default()).collect()
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.handle.shutdown();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// the per-shard loop
// ---------------------------------------------------------------------------

/// One shard's published load counters, readable by any sibling shard
/// rendering the fleet accept-load report.  Each shard *stores* its own
/// `ReactorStats` snapshot here (relaxed, at the top and bottom of every
/// wake) and only ever *loads* its siblings' cells — a mid-wake scrape
/// may observe a shard between publishes, so cross-cell invariants
/// (Σ accepts == Σ conns_opened on a reuseport fleet) hold exactly only
/// at quiescence.
#[derive(Default)]
struct ShardLoad {
    accepts: AtomicU64,
    conns_opened: AtomicU64,
    conns_closed: AtomicU64,
    open_conns: AtomicU64,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    wakes: AtomicU64,
}

/// Per-shard metrics state: the registry (for rendering scrapes), the
/// fleet's shared load cells, and this shard's pre-registered
/// histograms so a record is one `Arc` deref + one relaxed atomic add.
struct ShardMetrics {
    registry: Arc<MetricsRegistry>,
    load: Arc<Vec<ShardLoad>>,
    /// `ce_reactor_conn_lifetime_ns{shard="N"}` — admit to close.
    conn_lifetime: Arc<LatencyHist>,
    /// `ce_reactor_write_queue_wait_ns{shard="N"}` — how long the
    /// outbound queue stayed non-empty before fully draining (slow
    /// reader residency).
    wq_wait: Arc<LatencyHist>,
    /// `ce_reactor_ingest_frame_bytes{shard="N"}` — upload frame sizes
    /// (value-scaled; see [`crate::metrics::hist::VALUE_SCALE`]).
    ingest_bytes: Arc<LatencyHist>,
}

impl ShardMetrics {
    fn new(registry: Arc<MetricsRegistry>, load: Arc<Vec<ShardLoad>>, shard: usize) -> Self {
        let h = |name: &str| registry.hist(&format!("{name}{{shard=\"{shard}\"}}"));
        ShardMetrics {
            conn_lifetime: h("ce_reactor_conn_lifetime_ns"),
            wq_wait: h("ce_reactor_write_queue_wait_ns"),
            ingest_bytes: h("ce_reactor_ingest_frame_bytes"),
            registry,
            load,
        }
    }
}

/// Render the fleet accept-load report from the shared load cells:
/// per-shard samples plus an unlabeled fleet aggregate for each family,
/// in Prometheus text format (same exposition the registry renders).
fn render_load_report(load: &[ShardLoad]) -> String {
    type Field = (&'static str, &'static str, fn(&ShardLoad) -> u64);
    let fields: [Field; 7] = [
        ("ce_reactor_accepts", "counter", |l| l.accepts.load(Ordering::Relaxed)),
        ("ce_reactor_conns_opened", "counter", |l| l.conns_opened.load(Ordering::Relaxed)),
        ("ce_reactor_conns_closed", "counter", |l| l.conns_closed.load(Ordering::Relaxed)),
        ("ce_reactor_open_conns", "gauge", |l| l.open_conns.load(Ordering::Relaxed)),
        ("ce_reactor_frames_in", "counter", |l| l.frames_in.load(Ordering::Relaxed)),
        ("ce_reactor_frames_out", "counter", |l| l.frames_out.load(Ordering::Relaxed)),
        ("ce_reactor_wakes", "counter", |l| l.wakes.load(Ordering::Relaxed)),
    ];
    let mut out = String::new();
    for (name, kind, read) in fields {
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        let mut total = 0u64;
        for (i, cell) in load.iter().enumerate() {
            let v = read(cell);
            total += v;
            out.push_str(&format!("{name}{{shard=\"{i}\"}} {v}\n"));
        }
        out.push_str(&format!("{name} {total}\n"));
    }
    out
}

/// Outcome of one sniffer pass over an undecided connection (see
/// [`Loop::sniff_readable`]).
enum Sniff {
    /// This event is finished: bytes held pending a decision, a scrape
    /// was served, or the connection closed.
    Done,
    /// Decided: a protocol peer.  The held bytes went through the
    /// codec; these are the frames they completed, and the normal read
    /// path should continue within the same event.
    Frames(Vec<Vec<u8>>),
}

#[derive(Debug, Clone, Copy)]
enum ConnState {
    /// Handshake pending: the first frame must be a `Hello`.
    AwaitingHello,
    Active { device: u64, session: u64, channel: Channel },
}

struct Conn {
    id: u64,
    stream: TcpStream,
    codec: FrameCodec,
    state: ConnState,
    /// Registration time — bounds how long a handshake may stay pending.
    opened: Instant,
    /// Last successful byte read from or written to the peer — the
    /// established-connection idle clock
    /// ([`ReactorConfig::idle_timeout_s`]).
    last_activity: Instant,
    /// Reads paused by worker backpressure.
    paused: bool,
    /// Inbound frames routed so far — the ordinal the fault hook keys
    /// on ([`ReactorFault::sever_in_at`]).
    frames_seen: u64,
    /// Close as soon as the write queue drains (protocol error sent).
    closing: bool,
    /// Interest currently installed in the event set; [`Loop::
    /// sync_interest`] reconciles it after state changes.
    interest: Interest,
    /// First bytes held while deciding protocol vs `GET /metrics`
    /// (metrics on + un-Hello'd only; `None` once decided or when
    /// metrics are off — the normal read path then runs untouched).
    sniff: Option<Vec<u8>>,
    /// One-slot hold-and-release queue for the `reorder_in:<n>:<k>`
    /// fault: the held frame routes right after frame `n + k` does, and
    /// is silently lost if the connection closes first.
    held_frame: Option<Vec<u8>>,
    /// When the outbound queue last went empty→non-empty; resolved into
    /// the write-queue-residency histogram when it fully drains.
    wq_since: Option<Instant>,
}

struct Loop {
    /// This shard's index in the fleet — the tag its conn ids carry.
    shard: usize,
    router: Router,
    dims: ModelDims,
    cfg: ReactorConfig,
    wake_rx: WakeStream,
    listener: Option<TcpListener>,
    ctl_rx: Receiver<Ctl>,
    comp_tx: Sender<Completion>,
    comp_rx: Receiver<Completion>,
    waker: Waker,
    events: EventSet,
    /// Reused readiness buffer (taken/restored around each dispatch).
    evbuf: Vec<Event>,
    conns: HashMap<u64, Conn>,
    /// Shard-local id counter; ids handed out are `tag_conn(shard, ·)`.
    next_local: u64,
    scratch: Vec<u8>,
    stats: ReactorStats,
    /// Deterministic fault schedule every connection of this shard runs
    /// under (`None` in production — see [`ReactorFault::resolve`]).
    fault: Option<ReactorFault>,
    /// Trace recorder; `None` (the default) keeps the hot path at one
    /// `Option` check per tap site.
    sink: Option<Arc<TraceSink>>,
    /// Histogram handles + shared load cells; `None` (the default)
    /// keeps every record site at one `Option` check.
    metrics: Option<ShardMetrics>,
    /// Connections still awaiting their Hello — gates the reap scan and
    /// the bounded wait timeout (maintained at admit / handshake /
    /// close).
    pending_hellos: usize,
    /// Whether any connection was left paused by the last backpressure
    /// sweep — lets the sweep early-exit in the common unloaded case.
    paused_conns: bool,
    shutdown: bool,
}

impl Loop {
    /// Emit one trace event when recording is on.  Event construction
    /// (the closure) only runs behind the `Option` check, and a
    /// saturated sink drops the event and counts it — the shard never
    /// blocks on the recorder.
    fn trace_with(&mut self, build: impl FnOnce(u64) -> Ev) {
        if let Some(sink) = &self.sink {
            if sink.emit(build(self.shard as u64)) {
                self.stats.trace_events += 1;
            } else {
                self.stats.trace_dropped += 1;
            }
        }
    }

    /// Trace one injected fault at the per-conn ordinal it fired on.
    fn trace_fault(&mut self, id: u64, kind: &'static str, ordinal: u64) {
        self.trace_with(|shard| {
            Ev::new("fault")
                .u("shard", shard)
                .u("conn", local_of(id))
                .s("kind", kind)
                .u("ordinal", ordinal)
        });
    }

    fn run(mut self) -> ReactorStats {
        self.stats.backend = self.events.backend_name();
        if let Err(e) = self.events.register(raw_fd(&self.wake_rx), WAKE_TOKEN, Interest::READ) {
            log::error!("reactor shard {}: cannot watch the wake channel: {e}", self.shard);
            return self.stats;
        }
        if let Some(l) = &self.listener {
            let armed = l.set_nonblocking(true).is_ok()
                && self.events.register(raw_fd(l), LISTEN_TOKEN, Interest::READ).is_ok();
            if !armed {
                log::error!(
                    "reactor shard {}: cannot watch the listener fd; \
                     it will not accept connections",
                    self.shard
                );
                self.listener = None;
            }
        }
        loop {
            // channels first, wait second: a sender that raced past our
            // drain has also written a wake byte we have not read yet,
            // so the wait below cannot sleep through it
            self.drain_ctl();
            if self.shutdown {
                break;
            }
            self.publish_load();
            self.drain_completions();
            self.refresh_pauses();
            self.reap_stale_handshakes();
            self.reap_idle_conns();
            let timeout_ms = self.wait_timeout_ms();
            let mut evbuf = std::mem::take(&mut self.evbuf);
            evbuf.clear();
            if let Err(e) = self.events.wait(timeout_ms, &mut evbuf) {
                log::warn!("reactor {} wait failed: {e}", self.stats.backend);
                std::thread::sleep(Duration::from_millis(1));
            }
            self.stats.wakes += 1;
            self.stats.events_seen += evbuf.len() as u64;
            for ev in &evbuf {
                match ev.token {
                    WAKE_TOKEN => self.drain_wake(),
                    LISTEN_TOKEN => self.accept_ready(),
                    id => {
                        if ev.readable {
                            self.on_readable(id);
                        }
                        if ev.writable {
                            self.on_writable(id);
                        }
                    }
                }
            }
            self.evbuf = evbuf;
            self.publish_load();
        }
        // deterministic teardown: every socket is closed before the
        // thread exits, so joining the fleet proves no connection can
        // still produce a response
        let ids: Vec<u64> = self.conns.keys().copied().collect();
        for id in ids {
            self.close_conn(id, "server shutdown");
        }
        self.stats.open_conns = 0;
        self.publish_load();
        self.stats
    }

    /// Publish this shard's counters into its fleet load cell (top and
    /// bottom of every wake, and once at teardown).  Relaxed stores:
    /// the report is a monitoring snapshot, not a synchronization edge.
    fn publish_load(&self) {
        let Some(m) = &self.metrics else { return };
        let cell = &m.load[self.shard];
        cell.accepts.store(self.stats.accepts, Ordering::Relaxed);
        cell.conns_opened.store(self.stats.conns_opened, Ordering::Relaxed);
        cell.conns_closed.store(self.stats.conns_closed, Ordering::Relaxed);
        cell.open_conns.store(self.conns.len() as u64, Ordering::Relaxed);
        cell.frames_in.store(self.stats.frames_in, Ordering::Relaxed);
        cell.frames_out.store(self.stats.frames_out, Ordering::Relaxed);
        cell.wakes.store(self.stats.wakes, Ordering::Relaxed);
    }

    // -- control + completion channels --------------------------------------

    fn drain_ctl(&mut self) {
        while let Ok(ctl) = self.ctl_rx.try_recv() {
            match ctl {
                // register() streams are blocking-mode strangers; the
                // accept path admits pre-nonblocking sockets itself
                Ctl::Conn(stream) => self.admit(stream, false),
                Ctl::Stats(reply) => {
                    let mut s = self.stats.clone();
                    s.open_conns = self.conns.len();
                    let _ = reply.send(s);
                }
                Ctl::Shutdown => self.shutdown = true,
            }
        }
    }

    /// Admit one freshly accepted connection: per-shard `max_conns`
    /// share gate, then registration in the event set with the
    /// handshake timer armed.  `nonblocking` says the socket already is
    /// (Linux `accept4` admissions skip the extra fcntl).
    fn admit(&mut self, stream: TcpStream, nonblocking: bool) {
        if self.conns.len() >= self.cfg.max_conns {
            self.stats.conns_rejected += 1;
            log::warn!(
                "reactor shard {} at its max_conns share ({}); dropping new connection",
                self.shard,
                self.cfg.max_conns
            );
            return;
        }
        if !nonblocking && stream.set_nonblocking(true).is_err() {
            self.stats.conns_rejected += 1;
            return;
        }
        if stream.set_nodelay(true).is_err() {
            self.stats.conns_rejected += 1;
            return;
        }
        let id = tag_conn(self.shard, self.next_local);
        let interest = Interest::READ;
        if let Err(e) = self.events.register(raw_fd(&stream), id, interest) {
            log::warn!("reactor shard {}: cannot watch new connection: {e}", self.shard);
            self.stats.conns_rejected += 1;
            return;
        }
        self.next_local += 1; // ids never reused: stale completions cannot alias
        let now = Instant::now();
        self.conns.insert(
            id,
            Conn {
                id,
                stream,
                codec: FrameCodec::new(),
                state: ConnState::AwaitingHello,
                opened: now,
                last_activity: now,
                paused: false,
                closing: false,
                frames_seen: 0,
                interest,
                // sniffing exists only to serve scrapes, so its cost
                // (one held-prefix check per conn) is metrics-gated too
                sniff: self.metrics.is_some().then(Vec::new),
                held_frame: None,
                wq_since: None,
            },
        );
        self.stats.conns_opened += 1;
        self.pending_hellos += 1;
        self.trace_with(|shard| Ev::new("conn_open").u("shard", shard).u("conn", local_of(id)));
    }

    /// Accept until `WouldBlock`.  Edge-triggered caveat: the listener
    /// event is only re-delivered on a *new* arrival, so a non-transient
    /// accept failure (EMFILE under a burst) must not strand the
    /// connections already queued in the kernel backlog — the listener
    /// is explicitly re-armed (an identity `modify` re-delivers while
    /// the condition holds) and the retry is paced by a short sleep.
    /// With a shared accept queue, `WouldBlock` may simply mean a
    /// sibling shard won the race — same handling either way.
    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(l) => listener::accept_nonblocking(l),
                None => return,
            };
            match accepted {
                Ok(stream) => {
                    self.stats.accepts += 1;
                    self.admit(stream, true);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::Interrupted | io::ErrorKind::ConnectionAborted
                    ) =>
                {
                    continue; // transient: the next pending socket may be fine
                }
                Err(e) => {
                    // e.g. EMFILE: the backlog still holds accepted-able
                    // sockets, so keep the event coming (paced) instead
                    // of waiting for a SYN that may never arrive
                    log::warn!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(1));
                    if let Some(l) = &self.listener {
                        let _ = self.events.modify(raw_fd(l), LISTEN_TOKEN, Interest::READ);
                    }
                    return;
                }
            }
        }
    }

    fn drain_completions(&mut self) {
        while let Ok(done) = self.comp_rx.try_recv() {
            debug_assert_eq!(
                shard_of(done.conn),
                self.shard,
                "completion crossed shards: conn {:#x} on shard {}",
                done.conn,
                self.shard
            );
            if !self.conns.contains_key(&done.conn) {
                // connection-closed fencing: the socket is gone (peer
                // closed, evicted, or reset); ids are never reused — and
                // carry this shard's tag — so the response is dropped
                // instead of misdelivered
                continue;
            }
            let frame = match done.out {
                Ok(InferOutcome::Token(t)) => Message::TokenResponse {
                    req_id: done.req_id,
                    pos: done.pos,
                    token: t.token,
                    conf: t.conf,
                    compute_s: t.compute_s as f32,
                }
                .encode(),
                // context-store eviction: the edge replays its history
                // from position 0 and re-issues the request
                Ok(InferOutcome::Evicted) => Message::SessionEvicted {
                    device_id: done.device,
                    req_id: done.req_id,
                    pos: done.pos,
                }
                .encode(),
                Err(e) => Message::Error {
                    req_id: done.req_id,
                    pos: done.pos,
                    msg: format!("{e:#}"),
                }
                .encode(),
            };
            self.enqueue_and_flush(done.conn, &frame);
        }
    }

    fn drain_wake(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match self.wake_rx.read(&mut buf) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break, // WouldBlock: fully drained
            }
        }
    }

    /// Close connections that never completed their handshake.  Without
    /// this, sockets that connect and go silent would hold registration
    /// slots forever — and with `max_conns` admission, enough of them
    /// would lock every future device out.
    fn reap_stale_handshakes(&mut self) {
        if self.pending_hellos == 0 {
            return; // the scan only runs while handshakes are pending
        }
        let timeout = Duration::from_secs_f64(self.cfg.hello_timeout_s.max(0.001));
        let now = Instant::now();
        let stale: Vec<u64> = self
            .conns
            .values()
            .filter(|c| {
                matches!(c.state, ConnState::AwaitingHello)
                    && now.duration_since(c.opened) > timeout
            })
            .map(|c| c.id)
            .collect();
        for id in stale {
            self.stats.hello_timeouts += 1;
            self.close_conn(id, "no Hello within the handshake timeout");
        }
    }

    /// Close *established* connections whose peer has gone silent: no
    /// byte read from or written to them for `idle_timeout_s`.  A NAT
    /// table that expired, or a device that powered off mid-session,
    /// leaves a socket that never errors until written to — without this
    /// reap it holds an admission slot forever.  Reaping the connection
    /// also idles the device's cloud session, which the context store's
    /// TTL sweep then releases.
    fn reap_idle_conns(&mut self) {
        if self.cfg.idle_timeout_s <= 0.0 || self.conns.is_empty() {
            return;
        }
        let timeout = Duration::from_secs_f64(self.cfg.idle_timeout_s);
        let now = Instant::now();
        let stale: Vec<u64> = self
            .conns
            .values()
            .filter(|c| {
                // a backpressure-paused conn is not idle: the reactor is
                // refusing to read it, so its peer may be sending into
                // the kernel buffer this whole time
                !c.paused
                    && matches!(c.state, ConnState::Active { .. })
                    && now.saturating_duration_since(c.last_activity) > timeout
            })
            .map(|c| c.id)
            .collect();
        for id in stale {
            self.stats.idle_timeouts += 1;
            self.close_conn(id, "idle timeout (no reads or writes from peer)");
        }
    }

    /// Re-evaluate worker backpressure for every active connection this
    /// shard owns.  Overload is a per-worker property, so the queue
    /// depths are read once per worker, and the per-connection sweep
    /// runs only when there is something to pause or unpause.  Pause
    /// state lands in the event set as an interest change per affected
    /// connection.
    fn refresh_pauses(&mut self) {
        let cap = self.cfg.worker_queue_cap;
        let overloaded: Vec<bool> =
            (0..self.router.workers()).map(|w| self.router.queue_depth(w) > cap).collect();
        if !self.paused_conns && !overloaded.iter().any(|&o| o) {
            return; // nothing paused, nothing to pause
        }
        let mut still_paused = false;
        let mut changed: Vec<u64> = Vec::new();
        for c in self.conns.values_mut() {
            if let ConnState::Active { device, .. } = c.state {
                let o = overloaded[self.router.worker_for(device)];
                if o != c.paused {
                    if o {
                        self.stats.read_pauses += 1;
                    } else {
                        // resuming reads: the pause was the reactor's
                        // doing, so the quiet stretch must not count
                        // toward the peer's idle timeout
                        c.last_activity = Instant::now();
                    }
                    changed.push(c.id);
                }
                c.paused = o;
                still_paused |= o;
            }
        }
        self.paused_conns = still_paused;
        for id in changed {
            self.sync_interest(id);
        }
    }

    // -- readiness ----------------------------------------------------------

    /// How long the next wait may sleep.  Paused reads re-check worker
    /// queues at a 2ms cadence (workers do not wake the reactor when
    /// they catch up); pending handshakes and armed idle timeouts need
    /// bounded sleeps so a silent socket still hits its reap deadline.
    /// Otherwise: sleep until an event or a cross-thread wake.
    fn wait_timeout_ms(&self) -> i32 {
        if self.paused_conns {
            return 2;
        }
        let mut t: i64 = if self.pending_hellos > 0 { 500 } else { -1 };
        if self.cfg.idle_timeout_s > 0.0 && !self.conns.is_empty() {
            // O(conns) deadline scan, but only while the opt-in idle
            // reap is armed
            let idle = Duration::from_secs_f64(self.cfg.idle_timeout_s);
            let oldest = self
                .conns
                .values()
                .filter(|c| !c.paused && matches!(c.state, ConnState::Active { .. }))
                .map(|c| c.last_activity)
                .min();
            if let Some(oldest) = oldest {
                let ms =
                    (oldest + idle).saturating_duration_since(Instant::now()).as_millis() as i64;
                // floor keeps a just-missed deadline from busy-spinning;
                // cap keeps the reap cadence reasonable
                let ms = (ms + 1).clamp(10, 60_000);
                t = if t < 0 { ms } else { t.min(ms) };
            }
        }
        t as i32
    }

    /// Align the event set's interest with the connection's state — an
    /// O(1) `epoll_ctl` on the epoll backend, a map write on poll.
    /// Re-arming read interest on a socket whose bytes arrived while
    /// paused re-delivers the edge, so resume cannot stall.
    fn sync_interest(&mut self, id: u64) {
        let Some(c) = self.conns.get_mut(&id) else { return };
        let want = Interest {
            readable: !c.paused && !c.closing,
            writable: c.codec.pending_out() > 0,
        };
        if want == c.interest {
            return;
        }
        match self.events.modify(raw_fd(&c.stream), id, want) {
            Ok(()) => c.interest = want,
            Err(e) => log::warn!("reactor: interest change failed for conn {id}: {e}"),
        }
    }

    /// Advance the write-queue residency clock after a flush: start it
    /// on the empty→non-empty transition, resolve it into the
    /// histogram once the queue fully drains.  Metrics-off connections
    /// never reach the per-conn lookup.
    fn note_wq(&mut self, id: u64) {
        let Some(m) = &self.metrics else { return };
        if let Some(c) = self.conns.get_mut(&id) {
            if c.codec.pending_out() > 0 {
                c.wq_since.get_or_insert_with(Instant::now);
            } else if let Some(t0) = c.wq_since.take() {
                m.wq_wait.record_duration(t0.elapsed());
            }
        }
    }

    // -- per-connection I/O --------------------------------------------------

    /// Decide whether an un-Hello'd connection is a protocol peer or a
    /// plain-HTTP metrics scrape.  One nonblocking read per event; the
    /// bytes are held until the first 4 decide (`b"GET "` cannot open a
    /// valid frame: as a little-endian length it names a ~542 MB frame,
    /// far over the codec's cap).  A scrape gets the exposition over
    /// HTTP/1.0 and the connection closes; anything else is fed to the
    /// codec and framing resumes as if the sniffer were never there.
    /// Undecided connections stay `AwaitingHello`, so the handshake
    /// reaper bounds how long a silent prefix may hold a slot.
    fn sniff_readable(&mut self, id: u64) -> Sniff {
        const GET: &[u8] = b"GET ";
        let mut buf = [0u8; 4096];
        let decided = {
            let Some(c) = self.conns.get_mut(&id) else { return Sniff::Done };
            let n = match c.stream.read(&mut buf) {
                Ok(0) => {
                    self.close_conn(id, "peer closed");
                    return Sniff::Done;
                }
                Ok(n) => n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Sniff::Done,
                // Interrupted: the still-armed read interest retries
                Err(e) if e.kind() == io::ErrorKind::Interrupted => return Sniff::Done,
                Err(e) => {
                    let msg = format!("read failed: {e}");
                    self.close_conn(id, &msg);
                    return Sniff::Done;
                }
            };
            c.last_activity = Instant::now();
            let held = c.sniff.as_mut().expect("sniff_readable needs held-prefix state");
            held.extend_from_slice(&buf[..n]);
            if held.len() >= GET.len() {
                Some(held.starts_with(GET))
            } else if !GET.starts_with(held.as_slice()) {
                Some(false) // shorter than "GET " but already diverged
            } else {
                None // proper prefix: hold for more bytes
            }
        };
        match decided {
            None => Sniff::Done,
            Some(true) => {
                self.serve_metrics(id);
                Sniff::Done
            }
            Some(false) => {
                let held = self.conns.get_mut(&id).and_then(|c| c.sniff.take());
                let mut frames = Vec::new();
                if let Some(c) = self.conns.get_mut(&id) {
                    if let Err(e) = c.codec.feed_all(&held.unwrap_or_default(), &mut frames) {
                        let msg = format!("bad frame: {e:#}");
                        self.close_conn(id, &msg);
                        return Sniff::Done;
                    }
                }
                Sniff::Frames(frames)
            }
        }
    }

    /// Serve one `GET /metrics` scrape: render the registry exposition
    /// plus the fleet accept-load report, queue it behind a minimal
    /// HTTP/1.0 header, and close once the socket drains.  The request
    /// tail is read off first so closing cannot RST the response away.
    fn serve_metrics(&mut self, id: u64) {
        self.publish_load(); // this shard's own cell is fresh in the report
        let body = self.render_metrics();
        let head = format!(
            "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        let mut drain = [0u8; 4096];
        let mut fail: Option<String> = None;
        let mut drained = false;
        if let Some(c) = self.conns.get_mut(&id) {
            loop {
                match c.stream.read(&mut drain) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => continue,
                }
            }
            c.sniff = None;
            c.codec.enqueue_raw(head.as_bytes());
            c.codec.enqueue_raw(body.as_bytes());
            c.closing = true;
            match flush_conn(c) {
                Err(e) => fail = Some(format!("write failed: {e}")),
                Ok(()) => drained = c.codec.pending_out() == 0,
            }
        }
        self.note_wq(id);
        if let Some(reason) = fail {
            self.close_conn(id, &reason);
        } else if drained {
            self.close_conn(id, "metrics scrape served");
        } else {
            self.sync_interest(id); // write interest finishes the response
        }
    }

    /// The full exposition one scrape returns: every registered series
    /// (scheduler, reactor shards, edge) plus the fleet load report.
    fn render_metrics(&self) -> String {
        let Some(m) = &self.metrics else { return String::new() };
        let mut out = m.registry.render_prometheus();
        out.push_str(&render_load_report(&m.load));
        out
    }

    fn on_readable(&mut self, id: u64) {
        // undecided connections route through the sniffer first: it
        // either finishes the event (held / scrape served / closed) or
        // hands back the frames its held bytes completed and lets the
        // normal read path continue
        let pre: Vec<Vec<u8>> = if self.conns.get(&id).is_some_and(|c| c.sniff.is_some()) {
            match self.sniff_readable(id) {
                Sniff::Done => return,
                Sniff::Frames(frames) => frames,
            }
        } else {
            Vec::new()
        };
        let mut scratch = std::mem::take(&mut self.scratch);
        let (frames, close, more) = match self.conns.get_mut(&id) {
            Some(c) => read_frames(c, &mut scratch),
            None => {
                self.scratch = scratch;
                return;
            }
        };
        self.scratch = scratch;
        // frames completed before any poison/EOF are still routed
        for frame in pre.into_iter().chain(frames) {
            // a mid-batch protocol error closes (or marks closing) the
            // conn; later frames are void
            match self.conns.get(&id) {
                Some(c) if !c.closing => {}
                _ => break,
            }
            if let Err(e) = self.on_frame(id, frame) {
                self.close_conn(id, &format!("{e:#}"));
                break;
            }
        }
        if let Some(reason) = close {
            self.close_conn(id, &reason); // idempotent if already closed
        } else {
            self.sync_interest(id); // closing/write-queue state may have changed
            if more {
                // read budget exhausted with bytes likely still queued:
                // re-deliver the event instead of reading on, so other
                // connections, completions, and the backpressure sweep
                // interleave with this peer's stream
                self.rearm(id);
            }
        }
    }

    /// Ask the event set to re-deliver `id`'s readiness on the next
    /// wait if its condition still holds — an identity `modify` (epoll
    /// re-checks on MOD; the poll/probe backends re-report pending data
    /// on every wait anyway).
    fn rearm(&mut self, id: u64) {
        let Some(c) = self.conns.get(&id) else { return };
        let _ = self.events.modify(raw_fd(&c.stream), id, c.interest);
    }

    fn on_writable(&mut self, id: u64) {
        let mut fail: Option<String> = None;
        let mut drained_closing = false;
        if let Some(c) = self.conns.get_mut(&id) {
            match flush_conn(c) {
                Err(e) => fail = Some(format!("write failed: {e}")),
                Ok(()) => drained_closing = c.closing && c.codec.pending_out() == 0,
            }
        }
        self.note_wq(id);
        if let Some(reason) = fail {
            self.close_conn(id, &reason);
        } else if drained_closing {
            self.close_conn(id, "closed after protocol error");
        } else {
            self.sync_interest(id); // disarm write interest once drained
        }
    }

    /// Handle one decoded frame.  `Err` means "close this connection".
    ///
    /// This is a thin fault-injection shim around [`Self::route_frame`]:
    /// a scripted `drop` discards the n-th inbound frame *instead of*
    /// routing it (the ordinal still advances — a lost frame is still a
    /// received frame), a `delay` stalls the shard before routing (a
    /// slow middlebox), a `reorder` holds the n-th frame in the conn's
    /// one-slot queue and routes it right after frame `n + k` (frames
    /// in between overtake it — multipath reordering), and a `sever`
    /// fires only *after* the frame was acted on — modelling a crash
    /// with state advanced and the acknowledgement lost, the hardest
    /// case for the client.
    fn on_frame(&mut self, id: u64, frame: Vec<u8>) -> Result<()> {
        self.stats.frames_in += 1;
        let ordinal = match self.conns.get_mut(&id) {
            Some(c) => {
                let o = c.frames_seen;
                c.frames_seen += 1;
                o
            }
            None => return Ok(()),
        };
        self.trace_with(|shard| {
            Ev::new("frame_in")
                .u("shard", shard)
                .u("conn", local_of(id))
                .u("ordinal", ordinal)
                .u("tag", frame.first().copied().unwrap_or(0) as u64)
                .u("len", frame.len() as u64)
        });
        if let Some(f) = self.fault {
            if f.drop_in_at == Some(ordinal) {
                self.stats.faults_injected += 1;
                self.trace_fault(id, "drop", ordinal);
                return Ok(());
            }
            if f.delay_in_at == Some(ordinal) {
                self.stats.faults_injected += 1;
                self.trace_fault(id, "delay", ordinal);
                std::thread::sleep(Duration::from_millis(f.delay_in_ms));
            }
            // hold frame n; frames n+1 .. n+k overtake it below.  A gap
            // of 0 degrades to immediate delivery (nothing to overtake).
            if f.reorder_in_at == Some(ordinal) && f.reorder_gap > 0 {
                self.stats.faults_injected += 1;
                self.trace_fault(id, "reorder_hold", ordinal);
                if let Some(c) = self.conns.get_mut(&id) {
                    c.held_frame = Some(frame);
                }
                return Ok(());
            }
        }
        let mut out = self.route_frame(id, frame);
        if out.is_ok() {
            // release point: the overtaking frame routed, so the held
            // frame goes through now, out of order as scripted
            if let Some(f) = self.fault {
                if f.reorder_gap > 0 && f.reorder_in_at.map(|n| n + f.reorder_gap) == Some(ordinal)
                {
                    if let Some(held) = self.conns.get_mut(&id).and_then(|c| c.held_frame.take()) {
                        self.trace_fault(id, "reorder_release", ordinal);
                        out = self.route_frame(id, held);
                    }
                }
            }
        }
        if out.is_ok() {
            if let Some(n) = self.fault.and_then(|f| f.sever_in_at) {
                if ordinal == n {
                    self.stats.faults_injected += 1;
                    self.trace_fault(id, "sever", ordinal);
                    anyhow::bail!("fault injection: severed after inbound frame {n}");
                }
            }
        }
        out
    }

    /// Dispatch one decoded frame to the scheduler or protocol handler.
    fn route_frame(&mut self, id: u64, frame: Vec<u8>) -> Result<()> {
        let Some(state) = self.conns.get(&id).map(|c| c.state) else { return Ok(()) };
        match state {
            ConnState::AwaitingHello => {
                let (device_id, session, channel, resume, mirror) = match Message::decode(&frame)? {
                    Message::Hello { device_id, session, channel, resume, mirror } => {
                        (device_id, session, channel, resume, mirror)
                    }
                    other => anyhow::bail!("expected Hello, got {other:?}"),
                };
                if channel == Channel::Upload {
                    // fresh upload channel = fresh client session: reset
                    // the device and pin it to this session, queued ahead
                    // of everything the session will send (see the
                    // coordinator::cloud docs).  A resume Hello carries
                    // the SAME nonce and asks the worker to suspend
                    // (keep tombstones, drop state) instead of reset —
                    // the distinction lives in the scheduler, not here.
                    // The mirror bit rides along so the worker can bill
                    // warm-standby uploads separately.
                    self.router
                        .send(
                            device_id,
                            SchedMsg::Reset { device: device_id, session, resume, mirror },
                        )
                        .context("scheduler gone")?;
                }
                if let Some(c) = self.conns.get_mut(&id) {
                    c.state = ConnState::Active { device: device_id, session, channel };
                    self.pending_hellos = self.pending_hellos.saturating_sub(1);
                }
                log::debug!("device {device_id} opened {channel:?} channel (session {session:x})");
                self.enqueue_and_flush(id, &Message::Ack.encode());
                Ok(())
            }
            ConnState::Active { session, channel, .. } => {
                // zero-copy fast path for the dominant per-token frame
                // (payload borrowed from the frame buffer); the packed
                // bytes are forwarded as-is and the f16→f32 unpack runs
                // on the OWNING WORKER, so ingest CPU scales with the
                // pool instead of serializing on this one thread
                if let Some(v) = Message::decode_upload(&frame)? {
                    anyhow::ensure!(
                        v.payload.len() % (self.dims.d_model * v.precision.bytes_per_elem()) == 0,
                        "ragged upload"
                    );
                    let (device, req_id, start_pos, prompt_len, precision) =
                        (v.device_id, v.req_id, v.start_pos, v.prompt_len, v.precision);
                    if let Some(m) = &self.metrics {
                        m.ingest_bytes.record_value(frame.len() as u64);
                    }
                    return self
                        .router
                        .send(
                            device,
                            SchedMsg::Upload {
                                device,
                                session,
                                req_id,
                                start_pos,
                                prompt_len,
                                // the WHOLE frame moves to the worker —
                                // zero payload copies on this thread; a
                                // single-copy-ingested upload stays at
                                // one user-space copy end to end
                                payload: UploadPayload::PackedFrame { frame, precision },
                            },
                        )
                        .context("scheduler gone");
                }
                match Message::decode(&frame)? {
                    Message::InferRequest { device_id, req_id, pos, prompt_len, deadline_ms } => {
                        let deadline = (deadline_ms > 0)
                            .then(|| Instant::now() + Duration::from_millis(deadline_ms as u64));
                        // the Reply resolves to THIS shard: it captures
                        // this shard's completion channel and waker, and
                        // `conn` carries the shard tag, so the worker's
                        // answer cannot land anywhere else
                        let comp = self.comp_tx.clone();
                        let waker = self.waker.clone();
                        let conn = id;
                        let reply = Reply::new(move |out| {
                            let _ =
                                comp.send(Completion { conn, device: device_id, req_id, pos, out });
                            waker.wake();
                        });
                        self.router
                            .send(
                                device_id,
                                SchedMsg::Infer {
                                    device: device_id,
                                    session,
                                    req_id,
                                    pos,
                                    prompt_len,
                                    deadline,
                                    reply,
                                },
                            )
                            .context("scheduler gone")
                    }
                    Message::EndSession { device_id, req_id } => self
                        .router
                        .send(device_id, SchedMsg::End { device: device_id, session, req_id })
                        .context("scheduler gone"),
                    // keepalive probe: reflect the nonce without touching
                    // the scheduler — liveness must not depend on worker
                    // queue depth
                    Message::Ping { nonce } => {
                        self.enqueue_and_flush(id, &Message::Pong { nonce }.encode());
                        Ok(())
                    }
                    other => {
                        let msg = format!("unexpected message on {channel:?} channel: {other:?}");
                        log::debug!("reactor: {msg}");
                        self.enqueue_and_flush(
                            id,
                            &Message::Error { req_id: NO_REQ, pos: NO_REQ, msg }.encode(),
                        );
                        let drained = self
                            .conns
                            .get_mut(&id)
                            .map(|c| {
                                c.closing = true;
                                c.codec.pending_out() == 0
                            })
                            .unwrap_or(false);
                        if drained {
                            self.close_conn(id, "closed after protocol error");
                        }
                        Ok(())
                    }
                }
            }
        }
    }

    /// Queue one frame on `id`'s codec, flush what the socket accepts
    /// now, and evict the connection if its backlog exceeds the cap.
    fn enqueue_and_flush(&mut self, id: u64, payload: &[u8]) {
        let mut fail: Option<String> = None;
        let mut evict = false;
        let mut queued = false;
        if let Some(c) = self.conns.get_mut(&id) {
            match c.codec.enqueue_frame(payload) {
                Err(e) => fail = Some(format!("{e:#}")),
                Ok(()) => {
                    self.stats.frames_out += 1;
                    queued = true;
                    match flush_conn(c) {
                        Err(e) => fail = Some(format!("write failed: {e}")),
                        Ok(()) => evict = c.codec.pending_out() > self.cfg.write_queue_cap,
                    }
                }
            }
        }
        self.note_wq(id);
        if queued {
            self.trace_with(|shard| {
                Ev::new("frame_out")
                    .u("shard", shard)
                    .u("conn", local_of(id))
                    .u("tag", payload.first().copied().unwrap_or(0) as u64)
                    .u("len", payload.len() as u64)
            });
        }
        if let Some(reason) = fail {
            self.close_conn(id, &reason);
        } else if evict {
            self.stats.evicted_slow += 1;
            self.close_conn(id, "write queue over cap (slow reader evicted)");
        } else {
            self.sync_interest(id); // arm write interest for the backlog
        }
    }

    fn close_conn(&mut self, id: u64, reason: &str) {
        if let Some(c) = self.conns.remove(&id) {
            let _ = self.events.deregister(raw_fd(&c.stream), id);
            if matches!(c.state, ConnState::AwaitingHello) {
                self.pending_hellos = self.pending_hellos.saturating_sub(1);
            }
            if let Some(m) = &self.metrics {
                m.conn_lifetime.record_duration(c.opened.elapsed());
            }
            let _ = c.stream.shutdown(std::net::Shutdown::Both);
            self.stats.conns_closed += 1;
            log::debug!("reactor: connection {id} closed: {reason}");
            self.trace_with(|shard| {
                Ev::new("conn_close")
                    .u("shard", shard)
                    .u("conn", local_of(id))
                    .s("reason", reason)
            });
        }
    }
}

/// Cap on socket reads consumed by ONE readiness event (8 × 64 KiB
/// scratch reads ≈ 512 KiB): a single fast peer must not monopolize its
/// shard's thread, grow the frame batch without bound, or starve the
/// between-wakes backpressure sweep.  When the budget runs out the
/// event is re-armed ([`Loop::rearm`]) so the stream continues on the
/// next wake with everything else interleaved.
const READS_PER_EVENT: usize = 8;

/// Read until `WouldBlock` or the per-event budget, feeding the
/// connection's codec.  Large frame bodies land straight in their final
/// buffer through the codec's `read_slot` (single copy); everything
/// else batches through the shared scratch + `feed_all`.  Returns the
/// frames the reads completed, an optional close reason, and whether
/// the budget ran out with bytes likely still queued — valid frames
/// parsed before a poisoned one (or EOF) are still delivered, so an
/// upload in the same TCP segment as the corruption is not lost.
fn read_frames(c: &mut Conn, scratch: &mut [u8]) -> (Vec<Vec<u8>>, Option<String>, bool) {
    let mut frames = Vec::new();
    let mut reads = 0usize;
    loop {
        if reads >= READS_PER_EVENT {
            return (frames, None, true);
        }
        // one nonblocking read: into the frame's own buffer when the
        // codec is mid-large-frame, into scratch otherwise
        let read = if let Some(slot) = c.codec.read_slot() {
            c.stream.read(slot).map(|n| (n, true))
        } else {
            c.stream.read(scratch).map(|n| (n, false))
        };
        match read {
            Ok((0, _)) => return (frames, Some("peer closed".into()), false),
            Ok((n, direct)) => {
                reads += 1;
                c.last_activity = Instant::now();
                if direct {
                    c.codec.commit(n);
                } else if let Err(e) = c.codec.feed_all(&scratch[..n], &mut frames) {
                    return (frames, Some(format!("bad frame: {e:#}")), false);
                }
                // drain direct completions so frame order is preserved
                // across the two ingest styles
                loop {
                    match c.codec.next_frame() {
                        Ok(Some(f)) => frames.push(f),
                        Ok(None) => break,
                        Err(e) => return (frames, Some(format!("bad frame: {e:#}")), false),
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return (frames, None, false),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return (frames, Some(format!("read failed: {e}")), false),
        }
    }
}

/// Write as much of the connection's queue as the socket accepts now.
fn flush_conn(c: &mut Conn) -> io::Result<()> {
    while c.codec.pending_out() > 0 {
        match c.stream.write(c.codec.writable_bytes()) {
            Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "write returned 0")),
            Ok(n) => {
                c.last_activity = Instant::now();
                c.codec.consume_written(n);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conn_ids_are_shard_tagged_and_disjoint() {
        // the fencing invariant in miniature: two shards minting the
        // SAME local counter values produce disjoint conn ids, and each
        // id names its owner exactly
        for shard in [0usize, 1, 3, crate::config::MAX_REACTOR_SHARDS - 1] {
            for local in [1u64, 2, 1 << 20, (1 << SHARD_SHIFT) - 1] {
                let id = tag_conn(shard, local);
                assert_eq!(shard_of(id), shard, "shard round-trips through the tag");
                assert_ne!(id, WAKE_TOKEN, "tagged ids never collide with the wake token");
                assert_ne!(id, LISTEN_TOKEN, "tagged ids never collide with the listen token");
            }
        }
        let a = tag_conn(0, 42);
        let b = tag_conn(1, 42);
        assert_ne!(a, b, "same local id on different shards must differ");
    }

    #[test]
    fn stats_merge_sums_counters_and_keeps_labels() {
        let mut a = ReactorStats {
            conns_opened: 3,
            accepts: 2,
            wakes: 10,
            events_seen: 12,
            open_conns: 1,
            backend: "epoll",
            accept_mode: "reuseport",
            ..ReactorStats::default()
        };
        let b = ReactorStats {
            conns_opened: 4,
            accepts: 5,
            wakes: 7,
            events_seen: 9,
            open_conns: 2,
            evicted_slow: 1,
            backend: "epoll",
            accept_mode: "reuseport",
            ..ReactorStats::default()
        };
        a.merge(&b);
        assert_eq!(a.conns_opened, 7);
        assert_eq!(a.accepts, 7);
        assert_eq!(a.wakes, 17);
        assert_eq!(a.events_seen, 21);
        assert_eq!(a.open_conns, 3);
        assert_eq!(a.evicted_slow, 1);
        assert_eq!(a.backend, "epoll");
        assert_eq!(a.accept_mode, "reuseport");
        // merging into an empty aggregate adopts the labels
        let mut empty = ReactorStats::default();
        empty.merge(&b);
        assert_eq!(empty.backend, "epoll");
        assert_eq!(empty.accept_mode, "reuseport");
    }
}
