//! Virtual-time link: a FIFO channel with the [`LinkProfile`] cost model.
//!
//! Used by the discrete-event harness ([`crate::harness::des`]): transfers
//! occupy the link serially (uploads queue behind each other, which is what
//! makes the paper's "parallel upload" overlap matter), and each transfer
//! completes at `max(ready, link_free) + serialization + latency`.

use super::profiles::LinkProfile;

/// One direction of a simulated link, with FIFO serialization.
#[derive(Debug, Clone)]
pub struct SimLink {
    pub profile: LinkProfile,
    /// Virtual time when the link finishes serializing its last transfer.
    busy_until: f64,
    pub bytes_carried: u64,
    pub transfers: u64,
}

impl SimLink {
    pub fn new(profile: LinkProfile) -> Self {
        Self { profile, busy_until: 0.0, bytes_carried: 0, transfers: 0 }
    }

    /// Schedule a transfer that becomes ready to send at `ready_s`.
    /// Returns the virtual time at which it fully arrives.
    pub fn transfer(&mut self, ready_s: f64, bytes: usize) -> f64 {
        let start = ready_s.max(self.busy_until);
        // propagation latency overlaps with subsequent serializations; only
        // serialization occupies the link
        let ser = (bytes + self.profile.per_msg_overhead) as f64 / self.profile.bandwidth_bps;
        let ser = if ser.is_finite() { ser } else { 0.0 };
        self.busy_until = start + ser;
        self.bytes_carried += bytes as u64;
        self.transfers += 1;
        self.busy_until + self.profile.latency_s
    }

    /// Earliest time a new transfer could start serializing.
    pub fn free_at(&self) -> f64 {
        self.busy_until
    }

    pub fn reset(&mut self) {
        self.busy_until = 0.0;
        self.bytes_carried = 0;
        self.transfers = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> SimLink {
        // 1 MB/s, 10 ms latency, no overhead: easy arithmetic
        SimLink::new(LinkProfile {
            latency_s: 0.010,
            bandwidth_bps: 1e6,
            per_msg_overhead: 0,
            name: "test",
        })
    }

    #[test]
    fn single_transfer_time() {
        let mut l = link();
        // 100 kB at 1 MB/s = 0.1 s + 10 ms latency
        let done = l.transfer(0.0, 100_000);
        assert!((done - 0.110).abs() < 1e-9);
    }

    #[test]
    fn fifo_serialization_queues() {
        let mut l = link();
        let a = l.transfer(0.0, 100_000); // serializes 0.0..0.1
        let b = l.transfer(0.0, 100_000); // must wait: 0.1..0.2
        assert!((a - 0.110).abs() < 1e-9);
        assert!((b - 0.210).abs() < 1e-9);
        assert_eq!(l.transfers, 2);
        assert_eq!(l.bytes_carried, 200_000);
    }

    #[test]
    fn idle_gap_respected() {
        let mut l = link();
        l.transfer(0.0, 100_000);
        // ready long after the link is free: starts at ready time
        let c = l.transfer(5.0, 100_000);
        assert!((c - 5.110).abs() < 1e-9);
    }

    #[test]
    fn zero_byte_message_costs_latency_only() {
        let mut l = link();
        let done = l.transfer(1.0, 0);
        assert!((done - 1.010).abs() < 1e-9);
    }

    #[test]
    fn reset_clears_state() {
        let mut l = link();
        l.transfer(0.0, 1000);
        l.reset();
        assert_eq!(l.free_at(), 0.0);
        assert_eq!(l.bytes_carried, 0);
    }
}
