//! WAN link profiles.
//!
//! The paper's testbed connects an edge A100 box to a cloud A100 box over
//! a real network whose character (WiFi-class latency/bandwidth) drives
//! Table 2's communication column.  We model a link as one-way latency +
//! serialization bandwidth + per-message protocol overhead; the profile
//! used by each experiment is recorded in EXPERIMENTS.md.

/// A point-to-point link model (both directions symmetric).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkProfile {
    /// One-way propagation latency, seconds.
    pub latency_s: f64,
    /// Serialization bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Fixed framing/protocol overhead added to every message, bytes.
    pub per_msg_overhead: usize,
    pub name: &'static str,
}

impl LinkProfile {
    /// Time for one message of `bytes` payload to fully arrive.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes + self.per_msg_overhead) as f64 / self.bandwidth_bps
    }

    /// Campus/home WiFi — the paper calls out WiFi instability as a cloud
    /// deployment pain point; this is the default experiment profile.
    pub fn wifi() -> Self {
        Self {
            latency_s: 0.010,
            bandwidth_bps: 100e6 / 8.0, // 100 Mbit/s
            per_msg_overhead: 64,
            name: "wifi",
        }
    }

    /// Mobile LTE uplink.
    pub fn lte() -> Self {
        Self {
            latency_s: 0.040,
            bandwidth_bps: 30e6 / 8.0,
            per_msg_overhead: 64,
            name: "lte",
        }
    }

    /// Fibre WAN between datacentres.
    pub fn fiber() -> Self {
        Self {
            latency_s: 0.004,
            bandwidth_bps: 1e9 / 8.0,
            per_msg_overhead: 64,
            name: "fiber",
        }
    }

    /// Same-rack LAN (used to sanity-check that comm costs vanish).
    pub fn lan() -> Self {
        Self {
            latency_s: 0.0002,
            bandwidth_bps: 10e9 / 8.0,
            per_msg_overhead: 64,
            name: "lan",
        }
    }

    /// Link scaled to preserve the paper testbed's *ratios* between
    /// communication and compute (EXPERIMENTS.md §Setup).  From Table 2
    /// one can back out their effective link: ~3 ms per-request latency
    /// (14.1 s comm / ~4.3 k requests at θ=0.8) and ~3.8 MB/s effective
    /// bandwidth (10.95 GB naïve / 2877 s).  Their full model costs
    /// ~43 ms/token; ours ~6 ms/token and our hidden states are 32×
    /// smaller (128 vs 4096 dims), giving: latency 3 ms × (6/43) ≈
    /// 0.45 ms, bandwidth 3.8 MB/s × (6/43) × ... ≈ 1 MB/s so that one
    /// fp16 hidden upload ≈ 5% of a token's compute, as in the paper.
    pub fn paper_scaled() -> Self {
        Self {
            latency_s: 0.00045,
            bandwidth_bps: 1.0e6,
            per_msg_overhead: 64,
            name: "paper",
        }
    }

    /// A zero-cost link (unit tests).
    pub fn ideal() -> Self {
        Self { latency_s: 0.0, bandwidth_bps: f64::INFINITY, per_msg_overhead: 0, name: "ideal" }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "wifi" => Some(Self::wifi()),
            "paper" => Some(Self::paper_scaled()),
            "lte" => Some(Self::lte()),
            "fiber" => Some(Self::fiber()),
            "lan" => Some(Self::lan()),
            "ideal" => Some(Self::ideal()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_monotone_in_bytes() {
        let l = LinkProfile::wifi();
        assert!(l.transfer_s(1000) < l.transfer_s(100_000));
        assert!(l.transfer_s(0) >= l.latency_s);
    }

    #[test]
    fn wifi_hidden_state_upload_cost_sane() {
        // one f16 hidden vector (128 dims) ≈ 256 B -> dominated by latency
        let l = LinkProfile::wifi();
        let t = l.transfer_s(256);
        assert!(t > 0.010 && t < 0.011, "{t}");
    }

    #[test]
    fn ideal_is_free() {
        assert_eq!(LinkProfile::ideal().transfer_s(1 << 30), 0.0);
    }

    #[test]
    fn ordering_of_profiles() {
        let big = 1_000_000;
        assert!(LinkProfile::lan().transfer_s(big) < LinkProfile::fiber().transfer_s(big));
        assert!(LinkProfile::fiber().transfer_s(big) < LinkProfile::wifi().transfer_s(big));
        assert!(LinkProfile::wifi().transfer_s(big) < LinkProfile::lte().transfer_s(big));
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(LinkProfile::by_name("wifi"), Some(LinkProfile::wifi()));
        assert!(LinkProfile::by_name("carrier-pigeon").is_none());
    }
}
