//! Blocking message transports: length-prefixed frames over TCP (the real
//! serve path) or in-process channels (tests), with an optional throttle
//! that emulates a WAN profile on localhost.
//!
//! Framing: `u32 LE payload length | payload`.  Payload encoding is the
//! coordinator's wire protocol ([`crate::coordinator::protocol`]).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::profiles::LinkProfile;

/// Maximum accepted frame (guards against corrupt length prefixes).
pub const MAX_FRAME: usize = 64 << 20;

/// A bidirectional, blocking message pipe.
pub trait Transport: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Receive with a deadline: `Ok(Some(frame))` on success, `Ok(None)`
    /// once `deadline` passes with no frame started.  Used by the edge's
    /// latency-aware exit (paper §4.4) so a slow or dead cloud cannot
    /// block token generation.  The default implementation cannot time
    /// out and simply blocks (implementations should override).
    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        let _ = deadline;
        self.recv().map(Some)
    }
    /// Bytes pushed through `send` so far (payload only).
    fn bytes_sent(&self) -> u64;
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

pub struct TcpTransport {
    stream: TcpStream,
    sent: u64,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(Self { stream, sent: 0 })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Self::new(stream)
    }

    pub fn try_clone(&self) -> Result<Self> {
        Ok(Self { stream: self.stream.try_clone()?, sent: self.sent })
    }

    /// Deadline-bounded receive.  A timeout *before the first byte* of a
    /// frame is a clean `None`; a timeout mid-frame is an error, because
    /// the length-prefixed stream can no longer be resynchronized.
    fn recv_until(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        let mut len = [0u8; 4];
        if !self.read_all_until(&mut len, deadline, true)? {
            return Ok(None);
        }
        let n = u32::from_le_bytes(len) as usize;
        anyhow::ensure!(n <= MAX_FRAME, "frame length {n} exceeds limit");
        let mut buf = vec![0u8; n];
        if !self.read_all_until(&mut buf, deadline, false)? {
            anyhow::bail!("deadline passed mid-frame ({n}-byte body)");
        }
        Ok(Some(buf))
    }

    /// Fill `buf` before `deadline`.  Returns `Ok(false)` only when
    /// nothing was consumed and `zero_ok` is set; a timeout after partial
    /// progress is always an error (framing would be lost).
    fn read_all_until(&mut self, buf: &mut [u8], deadline: Instant, zero_ok: bool) -> Result<bool> {
        let mut got = 0usize;
        while got < buf.len() {
            let now = Instant::now();
            if now >= deadline {
                if got == 0 && zero_ok {
                    return Ok(false);
                }
                anyhow::bail!("deadline passed mid-frame ({got}/{} bytes)", buf.len());
            }
            self.stream.set_read_timeout(Some(deadline - now)).context("set_read_timeout")?;
            match self.stream.read(&mut buf[got..]) {
                Ok(0) => anyhow::bail!("peer closed"),
                Ok(k) => got += k,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    // loop back: the deadline check above decides between
                    // a clean None and a mid-frame error
                }
                Err(e) => return Err(e).context("reading frame"),
            }
        }
        Ok(true)
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        anyhow::ensure!(frame.len() <= MAX_FRAME, "frame too large: {}", frame.len());
        self.stream.write_all(&(frame.len() as u32).to_le_bytes())?;
        self.stream.write_all(frame)?;
        self.sent += frame.len() as u64;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).context("reading frame length")?;
        let n = u32::from_le_bytes(len) as usize;
        anyhow::ensure!(n <= MAX_FRAME, "frame length {n} exceeds limit");
        let mut buf = vec![0u8; n];
        self.stream.read_exact(&mut buf).context("reading frame body")?;
        Ok(buf)
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        let out = self.recv_until(deadline);
        // always restore blocking mode for subsequent plain recv calls
        let _ = self.stream.set_read_timeout(None);
        out
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }
}

// ---------------------------------------------------------------------------
// In-process (tests, single-binary demos)
// ---------------------------------------------------------------------------

pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    sent: u64,
}

/// A connected pair of in-process transports.
pub fn in_proc_pair() -> (InProcTransport, InProcTransport) {
    let (tx_a, rx_b) = std::sync::mpsc::channel();
    let (tx_b, rx_a) = std::sync::mpsc::channel();
    (
        InProcTransport { tx: tx_a, rx: rx_a, sent: 0 },
        InProcTransport { tx: tx_b, rx: rx_b, sent: 0 },
    )
}

impl Transport for InProcTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.sent += frame.len() as u64;
        self.tx.send(frame.to_vec()).map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        match self.rx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
            Ok(f) => Ok(Some(f)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow::anyhow!("peer closed")),
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }
}

// ---------------------------------------------------------------------------
// WAN throttle
// ---------------------------------------------------------------------------

/// Wraps a transport and sleeps according to a [`LinkProfile`] on send,
/// so localhost round trips exhibit WAN-like cost in the serve example.
pub struct Throttled<T: Transport> {
    pub inner: T,
    pub profile: LinkProfile,
}

impl<T: Transport> Throttled<T> {
    pub fn new(inner: T, profile: LinkProfile) -> Self {
        Self { inner, profile }
    }
}

impl<T: Transport> Transport for Throttled<T> {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let delay = self.profile.transfer_s(frame.len());
        if delay > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(delay));
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.inner.recv()
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        self.inner.recv_deadline(deadline)
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_proc_roundtrip() {
        let (mut a, mut b) = in_proc_pair();
        a.send(b"hello").unwrap();
        assert_eq!(b.recv().unwrap(), b"hello");
        b.send(b"world").unwrap();
        assert_eq!(a.recv().unwrap(), b"world");
        assert_eq!(a.bytes_sent(), 5);
    }

    #[test]
    fn in_proc_detects_closed_peer() {
        let (mut a, b) = in_proc_pair();
        drop(b);
        assert!(a.send(b"x").is_err());
    }

    #[test]
    fn tcp_roundtrip_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let payload: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
        c.send(&payload).unwrap();
        assert_eq!(c.recv().unwrap(), payload);
        server.join().unwrap();
    }

    #[test]
    fn throttled_send_delays() {
        let (a, mut b) = in_proc_pair();
        let profile = LinkProfile {
            latency_s: 0.02,
            bandwidth_bps: f64::INFINITY,
            per_msg_overhead: 0,
            name: "t",
        };
        let mut t = Throttled::new(a, profile);
        let start = std::time::Instant::now();
        t.send(b"x").unwrap();
        assert!(start.elapsed() >= Duration::from_millis(19));
        assert_eq!(b.recv().unwrap(), b"x");
    }

    #[test]
    fn in_proc_recv_deadline() {
        let (mut a, mut b) = in_proc_pair();
        // nothing queued: clean timeout
        let t0 = Instant::now();
        assert!(a.recv_deadline(t0 + Duration::from_millis(20)).unwrap().is_none());
        assert!(t0.elapsed() >= Duration::from_millis(19));
        // queued frame delivered immediately
        b.send(b"late").unwrap();
        let got = a.recv_deadline(Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(got.unwrap(), b"late");
        // closed peer is an error, not a timeout
        drop(b);
        assert!(a.recv_deadline(Instant::now() + Duration::from_millis(10)).is_err());
    }

    #[test]
    fn tcp_recv_deadline_times_out_then_recovers() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            go_rx.recv().unwrap(); // hold the reply until the client timed out once
            t.send(b"finally").unwrap();
            t.recv().unwrap() // plain recv still works after deadline mode
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        assert!(c
            .recv_deadline(Instant::now() + Duration::from_millis(30))
            .unwrap()
            .is_none());
        go_tx.send(()).unwrap();
        let got = c.recv_deadline(Instant::now() + Duration::from_secs(10)).unwrap();
        assert_eq!(got.unwrap(), b"finally");
        c.send(b"ok").unwrap();
        assert_eq!(server.join().unwrap(), b"ok");
    }

    #[test]
    fn oversized_frame_rejected() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _srv = std::thread::spawn(move || {
            let _ = listener.accept();
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(c.send(&big).is_err());
    }
}
