//! Blocking message transports: length-prefixed frames over TCP (the real
//! serve path) or in-process channels (tests), with an optional throttle
//! that emulates a WAN profile on localhost.
//!
//! Framing: `u32 LE payload length | payload`.  Payload encoding is the
//! coordinator's wire protocol ([`crate::coordinator::protocol`]).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Duration;

use anyhow::{Context, Result};

use super::profiles::LinkProfile;

/// Maximum accepted frame (guards against corrupt length prefixes).
pub const MAX_FRAME: usize = 64 << 20;

/// A bidirectional, blocking message pipe.
pub trait Transport: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Bytes pushed through `send` so far (payload only).
    fn bytes_sent(&self) -> u64;
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

pub struct TcpTransport {
    stream: TcpStream,
    sent: u64,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(Self { stream, sent: 0 })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Self::new(stream)
    }

    pub fn try_clone(&self) -> Result<Self> {
        Ok(Self { stream: self.stream.try_clone()?, sent: self.sent })
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        anyhow::ensure!(frame.len() <= MAX_FRAME, "frame too large: {}", frame.len());
        self.stream.write_all(&(frame.len() as u32).to_le_bytes())?;
        self.stream.write_all(frame)?;
        self.sent += frame.len() as u64;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len = [0u8; 4];
        self.stream.read_exact(&mut len).context("reading frame length")?;
        let n = u32::from_le_bytes(len) as usize;
        anyhow::ensure!(n <= MAX_FRAME, "frame length {n} exceeds limit");
        let mut buf = vec![0u8; n];
        self.stream.read_exact(&mut buf).context("reading frame body")?;
        Ok(buf)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }
}

// ---------------------------------------------------------------------------
// In-process (tests, single-binary demos)
// ---------------------------------------------------------------------------

pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    sent: u64,
}

/// A connected pair of in-process transports.
pub fn in_proc_pair() -> (InProcTransport, InProcTransport) {
    let (tx_a, rx_b) = std::sync::mpsc::channel();
    let (tx_b, rx_a) = std::sync::mpsc::channel();
    (
        InProcTransport { tx: tx_a, rx: rx_a, sent: 0 },
        InProcTransport { tx: tx_b, rx: rx_b, sent: 0 },
    )
}

impl Transport for InProcTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.sent += frame.len() as u64;
        self.tx.send(frame.to_vec()).map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }
}

// ---------------------------------------------------------------------------
// WAN throttle
// ---------------------------------------------------------------------------

/// Wraps a transport and sleeps according to a [`LinkProfile`] on send,
/// so localhost round trips exhibit WAN-like cost in the serve example.
pub struct Throttled<T: Transport> {
    pub inner: T,
    pub profile: LinkProfile,
}

impl<T: Transport> Throttled<T> {
    pub fn new(inner: T, profile: LinkProfile) -> Self {
        Self { inner, profile }
    }
}

impl<T: Transport> Transport for Throttled<T> {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let delay = self.profile.transfer_s(frame.len());
        if delay > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(delay));
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.inner.recv()
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_proc_roundtrip() {
        let (mut a, mut b) = in_proc_pair();
        a.send(b"hello").unwrap();
        assert_eq!(b.recv().unwrap(), b"hello");
        b.send(b"world").unwrap();
        assert_eq!(a.recv().unwrap(), b"world");
        assert_eq!(a.bytes_sent(), 5);
    }

    #[test]
    fn in_proc_detects_closed_peer() {
        let (mut a, b) = in_proc_pair();
        drop(b);
        assert!(a.send(b"x").is_err());
    }

    #[test]
    fn tcp_roundtrip_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let payload: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
        c.send(&payload).unwrap();
        assert_eq!(c.recv().unwrap(), payload);
        server.join().unwrap();
    }

    #[test]
    fn throttled_send_delays() {
        let (a, mut b) = in_proc_pair();
        let profile = LinkProfile {
            latency_s: 0.02,
            bandwidth_bps: f64::INFINITY,
            per_msg_overhead: 0,
            name: "t",
        };
        let mut t = Throttled::new(a, profile);
        let start = std::time::Instant::now();
        t.send(b"x").unwrap();
        assert!(start.elapsed() >= Duration::from_millis(19));
        assert_eq!(b.recv().unwrap(), b"x");
    }

    #[test]
    fn oversized_frame_rejected() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _srv = std::thread::spawn(move || {
            let _ = listener.accept();
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(c.send(&big).is_err());
    }
}
