//! Blocking message transports — thin adapters over the sans-I/O
//! [`FrameCodec`]: length-prefixed frames over TCP (the edge side of the
//! real serve path) or in-process channels (tests), with an optional
//! throttle that emulates a WAN profile on localhost.
//!
//! All framing lives in [`crate::net::codec`]; these types only move
//! bytes between a codec and a socket/channel, so the wire parser exists
//! exactly once whether the peer is the event-driven cloud reactor
//! ([`crate::net::reactor`]), a blocking test double, or an in-process
//! pair.  Frames go out prefix+payload in one contiguous buffer — a
//! single `write` syscall where the old transport issued two — and
//! large frame bodies come *in* through the codec's reserve-then-fill
//! [`FrameCodec::read_slot`] path, read from the socket straight into
//! the frame's own buffer (`read_exact`'s single copy, resumable across
//! deadline timeouts).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::codec::{frame_prefix, FrameCodec};
use super::profiles::LinkProfile;

pub use super::codec::MAX_FRAME;

/// Payloads at least this large bypass the codec's staging buffer and go
/// out as two direct `write_all`s (prefix, then payload): for a
/// multi-megabyte prompt upload the avoided memcpy dwarfs the extra
/// syscall, while the small per-token frames keep the single-buffer,
/// single-syscall path.
const DIRECT_SEND_MIN: usize = 32 * 1024;

/// A bidirectional, blocking message pipe.
pub trait Transport: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Receive with a deadline: `Ok(Some(frame))` on success, `Ok(None)`
    /// once `deadline` passes with no complete frame.  Used by the
    /// edge's latency-aware exit (paper §4.4) so a slow or dead cloud
    /// cannot block token generation.  Any partial frame stays buffered
    /// in the codec, so a later receive resumes it losslessly.  The
    /// default implementation cannot time out and simply blocks
    /// (implementations should override).
    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        let _ = deadline;
        self.recv().map(Some)
    }
    /// Bytes pushed through `send` so far (payload only).
    fn bytes_sent(&self) -> u64;
}

// ---------------------------------------------------------------------------
// TCP
// ---------------------------------------------------------------------------

pub struct TcpTransport {
    stream: TcpStream,
    codec: FrameCodec,
    scratch: Vec<u8>,
    /// Payload bytes sent through the direct (large-frame) path.
    sent_direct: u64,
}

impl TcpTransport {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(Self {
            stream,
            codec: FrameCodec::new(),
            scratch: vec![0u8; 16 * 1024],
            sent_direct: 0,
        })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Self::new(stream)
    }

    /// Connect with a per-attempt bound (the reconnect dialer's
    /// `ReconnectPolicy::connect_timeout_s`): a cloud that is down hard
    /// fails fast, one that is black-holed fails in `timeout` instead
    /// of the kernel's minutes-long SYN retry ladder.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        use std::net::ToSocketAddrs;
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {addr}"))?
            .next()
            .with_context(|| format!("no address for {addr}"))?;
        let stream = TcpStream::connect_timeout(&sock, timeout)
            .with_context(|| format!("connect {addr}"))?;
        Self::new(stream)
    }

    /// Deadline-bounded receive.  Unlike the pre-codec transport, a
    /// timeout mid-frame is *not* fatal: the partial bytes stay in the
    /// codec and the next receive continues where this one stopped.
    fn recv_until(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        loop {
            if let Some(f) = self.codec.next_frame()? {
                return Ok(Some(f));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            self.stream.set_read_timeout(Some(deadline - now)).context("set_read_timeout")?;
            // mid-large-frame the codec offers the frame's own tail
            // (single copy); otherwise bytes stage through scratch
            let read = if let Some(slot) = self.codec.read_slot() {
                self.stream.read(slot).map(|n| (n, true))
            } else {
                self.stream.read(&mut self.scratch).map(|n| (n, false))
            };
            match read {
                Ok((0, _)) => anyhow::bail!("peer closed"),
                Ok((n, true)) => self.codec.commit(n),
                Ok((n, false)) => {
                    if let Some(f) = self.codec.feed(&self.scratch[..n])? {
                        return Ok(Some(f));
                    }
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    // loop back: the deadline check decides when to stop
                }
                Err(e) => return Err(e).context("reading frame"),
            }
        }
    }
}

impl Transport for TcpTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        // large frames skip the staging copy entirely (the codec's
        // queue is always drained here, so ordering cannot invert)
        if frame.len() >= DIRECT_SEND_MIN && self.codec.pending_out() == 0 {
            anyhow::ensure!(frame.len() <= MAX_FRAME, "frame too large: {}", frame.len());
            self.stream.write_all(&frame_prefix(frame.len())).context("writing frame")?;
            self.stream.write_all(frame).context("writing frame")?;
            self.sent_direct += frame.len() as u64;
            return Ok(());
        }
        // small frames: prefix + payload queued contiguously — one
        // write_all, which on an unthrottled socket is one syscall (vs
        // two in the pre-codec transport; see the hotpath bench's
        // "tcp frame send" pair)
        self.codec.enqueue_frame(frame)?;
        while self.codec.pending_out() > 0 {
            match self.stream.write(self.codec.writable_bytes()) {
                Ok(0) => anyhow::bail!("peer closed"),
                Ok(n) => self.codec.consume_written(n),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("writing frame"),
            }
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        loop {
            if let Some(f) = self.codec.next_frame()? {
                return Ok(f);
            }
            // mid-large-frame the codec offers the frame's own tail
            // (single copy); otherwise bytes stage through scratch
            let read = if let Some(slot) = self.codec.read_slot() {
                self.stream.read(slot).map(|n| (n, true))
            } else {
                self.stream.read(&mut self.scratch).map(|n| (n, false))
            };
            match read {
                Ok((0, _)) => anyhow::bail!("peer closed"),
                Ok((n, true)) => self.codec.commit(n),
                Ok((n, false)) => {
                    if let Some(f) = self.codec.feed(&self.scratch[..n])? {
                        return Ok(f);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("reading frame"),
            }
        }
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        let out = self.recv_until(deadline);
        // always restore blocking mode for subsequent plain recv calls
        let _ = self.stream.set_read_timeout(None);
        out
    }

    fn bytes_sent(&self) -> u64 {
        self.codec.payload_bytes_enqueued() + self.sent_direct
    }
}

// ---------------------------------------------------------------------------
// In-process (tests, single-binary demos)
// ---------------------------------------------------------------------------

/// In-process transport that still speaks the real wire format: sends
/// push codec-framed byte chunks through a channel, receives feed the
/// peer's chunks back through a codec.  Tests exercising these therefore
/// exercise the exact parser the TCP path and the reactor use.
pub struct InProcTransport {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    codec: FrameCodec,
}

/// A connected pair of in-process transports.
pub fn in_proc_pair() -> (InProcTransport, InProcTransport) {
    let (tx_a, rx_b) = std::sync::mpsc::channel();
    let (tx_b, rx_a) = std::sync::mpsc::channel();
    (
        InProcTransport { tx: tx_a, rx: rx_a, codec: FrameCodec::new() },
        InProcTransport { tx: tx_b, rx: rx_b, codec: FrameCodec::new() },
    )
}

impl Transport for InProcTransport {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.codec.enqueue_frame(frame)?;
        let wire = self.codec.writable_bytes().to_vec();
        self.codec.consume_written(wire.len());
        self.tx.send(wire).map_err(|_| anyhow::anyhow!("peer closed"))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        loop {
            if let Some(f) = self.codec.next_frame()? {
                return Ok(f);
            }
            let chunk = self.rx.recv().map_err(|_| anyhow::anyhow!("peer closed"))?;
            if let Some(f) = self.codec.feed(&chunk)? {
                return Ok(f);
            }
        }
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        loop {
            if let Some(f) = self.codec.next_frame()? {
                return Ok(Some(f));
            }
            let wait = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(wait) {
                Ok(chunk) => {
                    if let Some(f) = self.codec.feed(&chunk)? {
                        return Ok(Some(f));
                    }
                }
                Err(RecvTimeoutError::Timeout) => return Ok(None),
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow::anyhow!("peer closed"))
                }
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.codec.payload_bytes_enqueued()
    }
}

// ---------------------------------------------------------------------------
// WAN throttle
// ---------------------------------------------------------------------------

/// Wraps a transport and sleeps according to a [`LinkProfile`] on send,
/// so localhost round trips exhibit WAN-like cost in the serve example.
pub struct Throttled<T: Transport> {
    pub inner: T,
    pub profile: LinkProfile,
}

impl<T: Transport> Throttled<T> {
    pub fn new(inner: T, profile: LinkProfile) -> Self {
        Self { inner, profile }
    }
}

impl<T: Transport> Transport for Throttled<T> {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let delay = self.profile.transfer_s(frame.len());
        if delay > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(delay));
        }
        self.inner.send(frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.inner.recv()
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        self.inner.recv_deadline(deadline)
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_proc_roundtrip() {
        let (mut a, mut b) = in_proc_pair();
        a.send(b"hello").unwrap();
        assert_eq!(b.recv().unwrap(), b"hello");
        b.send(b"world").unwrap();
        assert_eq!(a.recv().unwrap(), b"world");
        assert_eq!(a.bytes_sent(), 5);
    }

    #[test]
    fn in_proc_detects_closed_peer() {
        let (mut a, b) = in_proc_pair();
        drop(b);
        assert!(a.send(b"x").is_err());
    }

    #[test]
    fn tcp_roundtrip_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            let msg = t.recv().unwrap();
            t.send(&msg).unwrap(); // echo
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let payload: Vec<u8> = (0..100_000u32).map(|i| i as u8).collect();
        c.send(&payload).unwrap();
        assert_eq!(c.recv().unwrap(), payload);
        assert_eq!(c.bytes_sent(), payload.len() as u64, "payload-only accounting");
        server.join().unwrap();
    }

    #[test]
    fn tcp_recv_handles_many_frames_per_read() {
        // burst of frames sent back-to-back: the receiver's codec must
        // separate them however the kernel coalesces the bytes
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            for i in 0..64u32 {
                t.send(&i.to_le_bytes()).unwrap();
            }
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        for i in 0..64u32 {
            assert_eq!(c.recv().unwrap(), i.to_le_bytes());
        }
        server.join().unwrap();
    }

    #[test]
    fn throttled_send_delays() {
        let (a, mut b) = in_proc_pair();
        let profile = LinkProfile {
            latency_s: 0.02,
            bandwidth_bps: f64::INFINITY,
            per_msg_overhead: 0,
            name: "t",
        };
        let mut t = Throttled::new(a, profile);
        let start = std::time::Instant::now();
        t.send(b"x").unwrap();
        assert!(start.elapsed() >= Duration::from_millis(19));
        assert_eq!(b.recv().unwrap(), b"x");
    }

    #[test]
    fn in_proc_recv_deadline() {
        let (mut a, mut b) = in_proc_pair();
        // nothing queued: clean timeout
        let t0 = Instant::now();
        assert!(a.recv_deadline(t0 + Duration::from_millis(20)).unwrap().is_none());
        assert!(t0.elapsed() >= Duration::from_millis(19));
        // queued frame delivered immediately
        b.send(b"late").unwrap();
        let got = a.recv_deadline(Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(got.unwrap(), b"late");
        // closed peer is an error, not a timeout
        drop(b);
        assert!(a.recv_deadline(Instant::now() + Duration::from_millis(10)).is_err());
    }

    #[test]
    fn tcp_recv_deadline_times_out_then_recovers() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut t = TcpTransport::new(stream).unwrap();
            go_rx.recv().unwrap(); // hold the reply until the client timed out once
            t.send(b"finally").unwrap();
            t.recv().unwrap() // plain recv still works after deadline mode
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        assert!(c
            .recv_deadline(Instant::now() + Duration::from_millis(30))
            .unwrap()
            .is_none());
        go_tx.send(()).unwrap();
        let got = c.recv_deadline(Instant::now() + Duration::from_secs(10)).unwrap();
        assert_eq!(got.unwrap(), b"finally");
        c.send(b"ok").unwrap();
        assert_eq!(server.join().unwrap(), b"ok");
    }

    #[test]
    fn tcp_deadline_mid_frame_resumes_losslessly() {
        // the pre-codec transport had to fail a deadline that struck
        // mid-frame (framing lost); the codec keeps the partial bytes,
        // so the next receive completes the frame byte-for-byte
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
        let server = std::thread::spawn(move || {
            let (mut stream, _) = listener.accept().unwrap();
            stream.set_nodelay(true).unwrap();
            let payload = [9u8; 32];
            // write the prefix and half the payload, then stall
            stream.write_all(&(payload.len() as u32).to_le_bytes()).unwrap();
            stream.write_all(&payload[..16]).unwrap();
            go_rx.recv().unwrap();
            stream.write_all(&payload[16..]).unwrap();
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        assert!(
            c.recv_deadline(Instant::now() + Duration::from_millis(60)).unwrap().is_none(),
            "mid-frame deadline is a clean timeout"
        );
        go_tx.send(()).unwrap();
        let got = c.recv_deadline(Instant::now() + Duration::from_secs(10)).unwrap();
        assert_eq!(got.unwrap(), vec![9u8; 32]);
        server.join().unwrap();
    }

    #[test]
    fn oversized_frame_rejected() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _srv = std::thread::spawn(move || {
            let _ = listener.accept();
        });
        let mut c = TcpTransport::connect(&addr.to_string()).unwrap();
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(c.send(&big).is_err());
    }
}
