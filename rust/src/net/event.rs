//! Readiness backends for the connection reactor: one [`EventSet`]
//! abstraction over **edge-triggered `epoll(7)`** (Linux) and
//! **`poll(2)`** (portable fallback), both declared straight against the
//! platform libc every Rust binary already links — same no-new-crate
//! discipline as the rest of the wire layer.
//!
//! An `EventSet` is single-owner state: the reactor fleet creates **one
//! set per shard** (each its own epoll instance / pollfd table), so
//! interest changes and wakes never contend across shards.  Sets are
//! fully independent — the same underlying file *description* may be
//! registered in several sets at once (the shared-accept fallback
//! registers dup'd listener fds in every shard's set; each dup is its
//! own fd with its own interest), and the kernel reports readiness to
//! each set that watches it.
//!
//! Why two backends: `poll(2)` rebuilds an O(conns) pollfd array on
//! every wake, which is the scalability wall once connection counts go
//! past a few thousand.  `epoll` splits the cost the right way —
//! interest changes (backpressure pause/resume, write-queue arming) are
//! O(1) `epoll_ctl` calls against a kernel-resident interest set, and a
//! wake costs only the connections that are actually ready.  The
//! reactor's per-wake work therefore stops depending on how many
//! sockets are registered.
//!
//! Contract shared by the backends (the reactor relies on all three):
//! * **Edge-triggered discipline** — consumers must read/write until
//!   `WouldBlock` after a readiness event.  The `poll` backend is
//!   level-triggered underneath, for which that discipline is simply
//!   a little eager; the `epoll` backend requires it.
//! * **Re-arm on modify** — changing interest on an fd whose condition
//!   already holds re-delivers the event (epoll's `EPOLL_CTL_MOD`
//!   semantics), so a paused-then-resumed connection whose bytes
//!   arrived mid-pause cannot stall.
//! * **Errors always surface** — `ERR`/`HUP` are reported even for fds
//!   with no registered interest, mapped onto `readable` so the next
//!   read observes the real error (or EOF) and the connection is
//!   reaped.
//!
//! Backend selection is a runtime decision ([`EventSet::new`]):
//! [`ReactorBackend::Auto`] honours the `CE_REACTOR_BACKEND=poll|epoll`
//! environment toggle (CI uses it to keep the portable loop from
//! rotting) and otherwise picks `epoll` on Linux, `poll` elsewhere.
//! Non-unix targets get a documented 1ms-cadence probe fallback.

use std::io;

use crate::config::ReactorBackend;

/// Identifies a registered fd in readiness reports.  The reactor uses
/// connection ids plus two reserved values for its wake channel and
/// listener.
pub type Token = u64;

#[cfg(unix)]
pub type SourceFd = std::os::unix::io::RawFd;
/// Non-unix targets have no poll/epoll; the probe backend keys on
/// tokens alone and ignores this value.
#[cfg(not(unix))]
pub type SourceFd = i32;

/// What a registered fd should report.  `ERR`/`HUP` are always
/// reported regardless of these flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest { readable: true, writable: false };
}

/// One readiness report.  `readable` includes error/hang-up conditions
/// so the consumer's next read observes them.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub token: Token,
    pub readable: bool,
    pub writable: bool,
}

/// Env var consulted by [`EventSet::new`] when the config says `Auto`.
pub const BACKEND_ENV: &str = "CE_REACTOR_BACKEND";

// ---------------------------------------------------------------------------
// poll(2)
// ---------------------------------------------------------------------------

/// `poll(2)` via the platform libc — keeps the default build
/// dependency-light (no `libc`/`mio` crate).
#[cfg(unix)]
mod sys {
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;

    #[repr(C)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    // nfds_t is `unsigned long` on linux, `unsigned int` on the BSDs/mac
    #[cfg(any(target_os = "linux", target_os = "android", target_os = "emscripten"))]
    type NFds = std::os::raw::c_ulong;
    #[cfg(not(any(target_os = "linux", target_os = "android", target_os = "emscripten")))]
    type NFds = std::os::raw::c_uint;

    extern "C" {
        #[link_name = "poll"]
        fn poll_raw(fds: *mut PollFd, nfds: NFds, timeout_ms: c_int) -> c_int;
    }

    /// Block until a registered fd is ready or `timeout_ms` passes
    /// (`-1` = forever).  EINTR retries transparently.
    pub fn poll(fds: &mut [PollFd], timeout_ms: c_int) -> std::io::Result<usize> {
        loop {
            let r = unsafe { poll_raw(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
            if r >= 0 {
                return Ok(r as usize);
            }
            let e = std::io::Error::last_os_error();
            if e.kind() != std::io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

/// The portable fallback: interest lives in a userspace registry and
/// every wait rebuilds the O(conns) pollfd array — exactly the cost the
/// epoll backend exists to remove.
#[cfg(unix)]
#[derive(Default)]
pub struct PollSet {
    fds: std::collections::HashMap<Token, (SourceFd, Interest)>,
}

#[cfg(unix)]
impl PollSet {
    fn register(&mut self, fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        self.fds.insert(token, (fd, interest));
        Ok(())
    }

    fn modify(&mut self, fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        self.fds.insert(token, (fd, interest));
        Ok(())
    }

    fn deregister(&mut self, _fd: SourceFd, token: Token) -> io::Result<()> {
        self.fds.remove(&token);
        Ok(())
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
        let mut pfds = Vec::with_capacity(self.fds.len());
        let mut tokens = Vec::with_capacity(self.fds.len());
        for (&token, &(fd, interest)) in &self.fds {
            let mut ev = 0i16;
            if interest.readable {
                ev |= sys::POLLIN;
            }
            if interest.writable {
                ev |= sys::POLLOUT;
            }
            // fds with events == 0 still report ERR/HUP, so a paused
            // connection whose peer vanished is reaped promptly
            pfds.push(sys::PollFd { fd, events: ev, revents: 0 });
            tokens.push(token);
        }
        sys::poll(&mut pfds, timeout_ms)?;
        let err_mask = sys::POLLERR | sys::POLLHUP | sys::POLLNVAL;
        for (token, f) in tokens.into_iter().zip(&pfds) {
            if f.revents == 0 {
                continue;
            }
            out.push(Event {
                token,
                // ERR/HUP surface through a read() so the real error
                // (or EOF) is observed by the consumer
                readable: f.revents & (sys::POLLIN | err_mask) != 0,
                writable: f.revents & sys::POLLOUT != 0,
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// epoll(7)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod esys {
    use std::os::raw::c_int;

    // matches the kernel ABI: packed on x86/x86_64, naturally aligned
    // elsewhere (same layout the libc crate declares)
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLET: u32 = 1 << 31;

    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;
    pub const EPOLL_CLOEXEC: c_int = 0o2000000;

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// Edge-triggered `epoll`: the interest set lives in the kernel, so
/// interest changes are single `epoll_ctl` syscalls and a wake returns
/// only the ready fds — per-wake work independent of connection count.
#[cfg(target_os = "linux")]
pub struct EpollSet {
    epfd: i32,
    /// Reused readiness buffer; 1024 ready fds per wake is far above
    /// what one dispatch round consumes.
    buf: Vec<esys::EpollEvent>,
}

#[cfg(target_os = "linux")]
impl EpollSet {
    fn new() -> io::Result<Self> {
        let epfd = unsafe { esys::epoll_create1(esys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Self { epfd, buf: vec![esys::EpollEvent { events: 0, data: 0 }; 1024] })
    }

    fn mask(interest: Interest) -> u32 {
        let mut m = esys::EPOLLET;
        if interest.readable {
            m |= esys::EPOLLIN;
        }
        if interest.writable {
            m |= esys::EPOLLOUT;
        }
        m
    }

    fn ctl(
        &self,
        op: std::os::raw::c_int,
        fd: SourceFd,
        token: Token,
        interest: Interest,
    ) -> io::Result<()> {
        // DEL ignores the event since 2.6.9 but a non-null pointer keeps
        // older kernels happy, so one shape serves all three ops
        let mut ev = esys::EpollEvent { events: Self::mask(interest), data: token };
        if unsafe { esys::epoll_ctl(self.epfd, op, fd, &mut ev) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn register(&mut self, fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        self.ctl(esys::EPOLL_CTL_ADD, fd, token, interest)
    }

    fn modify(&mut self, fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        self.ctl(esys::EPOLL_CTL_MOD, fd, token, interest)
    }

    fn deregister(&mut self, fd: SourceFd, token: Token) -> io::Result<()> {
        self.ctl(esys::EPOLL_CTL_DEL, fd, token, Interest::default())
    }

    fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
        loop {
            let n = unsafe {
                esys::epoll_wait(
                    self.epfd,
                    self.buf.as_mut_ptr(),
                    self.buf.len() as std::os::raw::c_int,
                    timeout_ms,
                )
            };
            if n >= 0 {
                for e in &self.buf[..n as usize] {
                    // copy fields out by value: EpollEvent is packed on
                    // x86, so references into it would be unaligned
                    let bits = e.events;
                    let token = e.data;
                    out.push(Event {
                        token,
                        readable: bits & (esys::EPOLLIN | esys::EPOLLERR | esys::EPOLLHUP) != 0,
                        writable: bits & esys::EPOLLOUT != 0,
                    });
                }
                return Ok(());
            }
            let e = io::Error::last_os_error();
            if e.kind() != io::ErrorKind::Interrupted {
                return Err(e);
            }
        }
    }
}

#[cfg(target_os = "linux")]
impl Drop for EpollSet {
    fn drop(&mut self) {
        unsafe {
            esys::close(self.epfd);
        }
    }
}

// ---------------------------------------------------------------------------
// non-unix probe fallback
// ---------------------------------------------------------------------------

/// Non-unix fallback: no kernel readiness at all — every wait sleeps
/// 1ms and reports each registered token ready per its interest; idle
/// probes cost the consumer one `WouldBlock` read.
#[cfg(not(unix))]
#[derive(Default)]
pub struct ProbeSet {
    fds: std::collections::HashMap<Token, Interest>,
}

#[cfg(not(unix))]
impl ProbeSet {
    fn register(&mut self, _fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        self.fds.insert(token, interest);
        Ok(())
    }

    fn modify(&mut self, _fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        self.fds.insert(token, interest);
        Ok(())
    }

    fn deregister(&mut self, _fd: SourceFd, token: Token) -> io::Result<()> {
        self.fds.remove(&token);
        Ok(())
    }

    fn wait(&mut self, _timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
        std::thread::sleep(std::time::Duration::from_millis(1));
        for (&token, &interest) in &self.fds {
            if interest.readable || interest.writable {
                out.push(Event { token, readable: interest.readable, writable: interest.writable });
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// the runtime-selected set
// ---------------------------------------------------------------------------

/// A runtime-selected readiness backend.  All variants share the
/// edge-triggered contract described in the module docs.
pub enum EventSet {
    #[cfg(unix)]
    Poll(PollSet),
    #[cfg(target_os = "linux")]
    Epoll(EpollSet),
    #[cfg(not(unix))]
    Probe(ProbeSet),
}

impl EventSet {
    /// Build the backend `requested` asks for.  `Auto` honours
    /// [`BACKEND_ENV`] and otherwise picks the platform default
    /// (`epoll` on Linux, `poll` elsewhere); an explicit `Epoll` off
    /// Linux degrades to `poll` with a warning rather than failing.
    pub fn new(requested: ReactorBackend) -> io::Result<EventSet> {
        let choice = match requested {
            ReactorBackend::Auto => match std::env::var(BACKEND_ENV).ok().as_deref() {
                Some("poll") => ReactorBackend::Poll,
                Some("epoll") => ReactorBackend::Epoll,
                Some(other) => {
                    log::warn!(
                        "{BACKEND_ENV}={other:?} not recognized (poll|epoll); \
                         using the platform default"
                    );
                    ReactorBackend::Auto
                }
                None => ReactorBackend::Auto,
            },
            explicit => explicit,
        };
        Self::build(choice)
    }

    #[cfg(target_os = "linux")]
    fn build(choice: ReactorBackend) -> io::Result<EventSet> {
        if matches!(choice, ReactorBackend::Poll) {
            return Ok(EventSet::Poll(PollSet::default()));
        }
        // Auto and Epoll both mean epoll here; fall back to poll only
        // if the kernel refuses an epoll instance
        match EpollSet::new() {
            Ok(set) => Ok(EventSet::Epoll(set)),
            Err(e) => {
                log::warn!("epoll unavailable ({e}); falling back to poll(2)");
                Ok(EventSet::Poll(PollSet::default()))
            }
        }
    }

    #[cfg(all(unix, not(target_os = "linux")))]
    fn build(choice: ReactorBackend) -> io::Result<EventSet> {
        if matches!(choice, ReactorBackend::Epoll) {
            log::warn!("epoll requested on a non-Linux platform; using poll(2)");
        }
        Ok(EventSet::Poll(PollSet::default()))
    }

    #[cfg(not(unix))]
    fn build(_choice: ReactorBackend) -> io::Result<EventSet> {
        Ok(EventSet::Probe(ProbeSet::default()))
    }

    /// Which backend actually runs (reported through `ReactorStats`).
    pub fn backend_name(&self) -> &'static str {
        match self {
            #[cfg(unix)]
            EventSet::Poll(_) => "poll",
            #[cfg(target_os = "linux")]
            EventSet::Epoll(_) => "epoll",
            #[cfg(not(unix))]
            EventSet::Probe(_) => "probe",
        }
    }

    /// Start watching `fd` under `token`.  If the condition already
    /// holds the event is delivered on the next wait.
    pub fn register(&mut self, fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            EventSet::Poll(s) => s.register(fd, token, interest),
            #[cfg(target_os = "linux")]
            EventSet::Epoll(s) => s.register(fd, token, interest),
            #[cfg(not(unix))]
            EventSet::Probe(s) => s.register(fd, token, interest),
        }
    }

    /// Change interest — O(1) on every backend (a map write or one
    /// `epoll_ctl`); re-delivers the event if the condition holds.
    pub fn modify(&mut self, fd: SourceFd, token: Token, interest: Interest) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            EventSet::Poll(s) => s.modify(fd, token, interest),
            #[cfg(target_os = "linux")]
            EventSet::Epoll(s) => s.modify(fd, token, interest),
            #[cfg(not(unix))]
            EventSet::Probe(s) => s.modify(fd, token, interest),
        }
    }

    /// Stop watching `fd`; call before closing it.
    pub fn deregister(&mut self, fd: SourceFd, token: Token) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            EventSet::Poll(s) => s.deregister(fd, token),
            #[cfg(target_os = "linux")]
            EventSet::Epoll(s) => s.deregister(fd, token),
            #[cfg(not(unix))]
            EventSet::Probe(s) => s.deregister(fd, token),
        }
    }

    /// Block until something is ready or `timeout_ms` passes (`-1` =
    /// forever), appending readiness reports to `out`.  EINTR retries
    /// transparently.
    pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> io::Result<()> {
        match self {
            #[cfg(unix)]
            EventSet::Poll(s) => s.wait(timeout_ms, out),
            #[cfg(target_os = "linux")]
            EventSet::Epoll(s) => s.wait(timeout_ms, out),
            #[cfg(not(unix))]
            EventSet::Probe(s) => s.wait(timeout_ms, out),
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;

    /// Shared behaviour check run against every backend the platform
    /// offers: registration surfaces readable data, modify masks and
    /// re-arms interest, deregister silences the fd.
    fn exercise(mut set: EventSet) {
        let (mut a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        b.set_nonblocking(true).unwrap();
        set.register(b.as_raw_fd(), 7, Interest::READ).unwrap();

        let mut events = Vec::new();
        // nothing ready: a zero-timeout wait reports nothing
        set.wait(0, &mut events).unwrap();
        assert!(
            events.iter().all(|e| e.token != 7),
            "{}: idle fd reported",
            set.backend_name()
        );

        a.write_all(b"x").unwrap();
        events.clear();
        set.wait(1000, &mut events).unwrap();
        let ev = events.iter().find(|e| e.token == 7).expect("readable event");
        assert!(ev.readable);

        // consume, then drop read interest: pending new bytes stay silent
        let mut buf = [0u8; 8];
        let _ = (&b).read(&mut buf).unwrap();
        set.modify(b.as_raw_fd(), 7, Interest::default()).unwrap();
        a.write_all(b"y").unwrap();
        events.clear();
        set.wait(0, &mut events).unwrap();
        assert!(
            events.iter().all(|e| e.token != 7 || !e.readable),
            "{}: read event delivered with interest dropped",
            set.backend_name()
        );

        // re-arming interest re-delivers the edge for bytes that
        // arrived while interest was off (the pause/resume contract)
        set.modify(b.as_raw_fd(), 7, Interest::READ).unwrap();
        events.clear();
        set.wait(1000, &mut events).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "{}: re-arm did not re-deliver pending bytes",
            set.backend_name()
        );

        set.deregister(b.as_raw_fd(), 7).unwrap();
        a.write_all(b"z").unwrap();
        events.clear();
        set.wait(0, &mut events).unwrap();
        assert!(
            events.iter().all(|e| e.token != 7),
            "{}: deregistered fd reported",
            set.backend_name()
        );
    }

    #[test]
    fn poll_backend_contract() {
        exercise(EventSet::new(crate::config::ReactorBackend::Poll).unwrap());
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_backend_contract() {
        let set = EventSet::new(crate::config::ReactorBackend::Epoll).unwrap();
        assert_eq!(set.backend_name(), "epoll");
        exercise(set);
    }
}
