//! Sans-I/O framing codec: the single place the wire format
//! (`u32 LE payload length | payload`) is produced and parsed.
//!
//! The codec performs **no I/O**.  Callers push whatever bytes their
//! socket happened to deliver through [`FrameCodec::feed`] and pop
//! complete frames; on the write side they queue frames with
//! [`FrameCodec::enqueue_frame`] and drain [`FrameCodec::writable_bytes`]
//! into the socket at whatever pace it accepts, acknowledging progress
//! with [`FrameCodec::consume_written`].  That inversion is what lets
//! one event-driven thread ([`crate::net::reactor`]) own thousands of
//! nonblocking sockets while the blocking adapters in
//! [`crate::net::transport`] wrap the very same parser — the protocol
//! framing exists exactly once.
//!
//! Properties:
//! * **Incremental**: bytes may arrive one at a time or many frames at
//!   once; partial frames persist across `feed` calls, so a read timeout
//!   mid-frame loses nothing (the blocking transports exploit this for
//!   deadline-bounded receives that can resume).
//! * **Early bounds check**: [`MAX_FRAME`] is enforced as soon as the
//!   four length bytes are visible — a corrupt length prefix fails the
//!   stream before any body bytes are buffered.
//! * **Single-buffer writes**: the length prefix and payload are queued
//!   contiguously, so one `write` syscall covers both (and possibly a
//!   whole run of queued frames) where the old transport issued two.
//! * **Backpressure-aware**: [`FrameCodec::pending_out`] exposes the
//!   unflushed byte count, which the reactor compares against its
//!   write-queue cap to evict slow readers.
//! * **Single-copy large-frame ingest**: once the length prefix of a
//!   frame with ≥ [`DIRECT_READ_MIN`] body bytes is visible, the codec
//!   switches to a reserve-then-fill mode — [`FrameCodec::read_slot`]
//!   hands out the frame's own unfilled tail and the caller reads from
//!   the fd straight into it ([`FrameCodec::commit`] acknowledges), so
//!   payload bytes go kernel → frame with no staging copy: `read_exact`'s
//!   single copy, without blocking I/O.  Small frames keep the buffered
//!   path, where one scratch read picks up many frames per syscall.

use std::collections::VecDeque;

use anyhow::{ensure, Result};

/// Maximum accepted frame (guards against corrupt length prefixes).
pub const MAX_FRAME: usize = 64 << 20;

/// Bytes of framing per message: the `u32` little-endian length prefix.
pub const FRAME_HEADER: usize = 4;

/// Largest buffer capacity a drained codec keeps around.  One near-
/// `MAX_FRAME` frame must not pin 64 MiB per connection for the rest of
/// its life; past this, drained buffers are released to the allocator.
const RETAIN_CAP: usize = 256 << 10;

/// Smallest frame body that flips the read side into direct
/// (reserve-then-fill) mode.  Below this, the staging copy through a
/// shared scratch buffer is cheaper than giving up read batching —
/// one 64 KiB scratch read ingests hundreds of per-token frames in a
/// single syscall, while a multi-read upload body goes straight into
/// its own allocation.
pub const DIRECT_READ_MIN: usize = 4096;

/// Wire bytes occupied by a frame carrying `payload_len` payload bytes.
/// The DES harness uses this so simulated wire costs track the real
/// codec's framing.
pub const fn frame_wire_len(payload_len: usize) -> usize {
    FRAME_HEADER + payload_len
}

/// The length prefix for a frame carrying `payload_len` payload bytes —
/// the one place the prefix encoding is written down.
pub fn frame_prefix(payload_len: usize) -> [u8; FRAME_HEADER] {
    (payload_len as u32).to_le_bytes()
}

/// Encode one frame into a fresh buffer (prefix + payload, contiguous).
/// One-shot convenience for paths that do not keep a codec around.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    let mut b = Vec::with_capacity(FRAME_HEADER + payload.len());
    b.extend_from_slice(&frame_prefix(payload.len()));
    b.extend_from_slice(payload);
    b
}

/// A large frame being filled in place: `buf` is the frame's final
/// allocation (length = announced body size), `filled` the bytes
/// received so far.  While one of these is live the read buffer is
/// empty — the partial frame has exactly one home.
#[derive(Debug)]
struct DirectFrame {
    buf: Vec<u8>,
    filled: usize,
}

/// Incremental, sans-I/O frame parser + write queue.  See the module
/// docs for the contract.
#[derive(Debug, Default)]
pub struct FrameCodec {
    /// Received-but-unparsed bytes; `in_pos` is the parse cursor.
    in_buf: Vec<u8>,
    in_pos: usize,
    /// In-progress large frame on the single-copy read path
    /// ([`Self::read_slot`] / [`Self::commit`]).
    direct: Option<DirectFrame>,
    /// Frames completed by the direct path, awaiting [`Self::next_frame`]
    /// (always older than anything still in `in_buf`).
    ready: VecDeque<Vec<u8>>,
    /// Queued-but-unwritten wire bytes; `out_pos` is the flush cursor.
    out_buf: Vec<u8>,
    out_pos: usize,
    frames_in: u64,
    frames_out: u64,
    /// Payload bytes enqueued so far (framing excluded) — feeds
    /// [`crate::net::transport::Transport::bytes_sent`].
    payload_bytes_out: u64,
}

impl FrameCodec {
    pub fn new() -> Self {
        Self::default()
    }

    // -- read half ----------------------------------------------------------

    /// Push freshly received bytes.  Returns the first frame they
    /// complete (if any); drain the rest with [`Self::next_frame`].
    /// An error poisons the stream: the length prefix can no longer be
    /// trusted and the connection should be dropped.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<Option<Vec<u8>>> {
        // a large frame mid-fill on the read-into path absorbs its
        // bytes first (callers may mix `feed` with `read_slot`)
        let bytes = self.fill_direct(bytes);
        // compact before growing so a long-lived connection's buffer
        // stays bounded by its largest in-flight frame
        if self.in_pos > 0 {
            self.in_buf.drain(..self.in_pos);
            self.in_pos = 0;
        }
        self.in_buf.extend_from_slice(bytes);
        self.next_frame()
    }

    /// Pop the next already-buffered complete frame.  `Ok(None)` means
    /// more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Vec<u8>>> {
        if let Some(f) = self.ready.pop_front() {
            // completed (and counted) by the direct read-into path;
            // always older than anything still buffered below
            return Ok(Some(f));
        }
        let avail = self.in_buf.len() - self.in_pos;
        if avail < FRAME_HEADER {
            return Ok(None);
        }
        let len: [u8; FRAME_HEADER] =
            self.in_buf[self.in_pos..self.in_pos + FRAME_HEADER].try_into().unwrap();
        let n = u32::from_le_bytes(len) as usize;
        // enforced mid-stream, before any body byte is buffered or even
        // received — a poisoned prefix cannot make us allocate 4 GiB
        ensure!(n <= MAX_FRAME, "frame length {n} exceeds limit");
        if avail < FRAME_HEADER + n {
            return Ok(None);
        }
        let start = self.in_pos + FRAME_HEADER;
        let frame = self.in_buf[start..start + n].to_vec();
        self.in_pos = start + n;
        if self.in_pos == self.in_buf.len() {
            self.in_pos = 0;
            if self.in_buf.capacity() > RETAIN_CAP {
                self.in_buf = Vec::new();
            } else {
                self.in_buf.clear();
            }
        }
        self.frames_in += 1;
        Ok(Some(frame))
    }

    /// Drain one received chunk into `out`, parsing whole frames
    /// **directly from `bytes`** whenever the read buffer is empty — on
    /// the bulk-ingest path (the reactor's 64 KiB socket reads) payload
    /// bytes then go kernel → scratch → frame without a staging copy
    /// through the codec's buffer.  Only an incomplete tail (or the
    /// completion of a previously buffered partial frame) touches
    /// `in_buf`.  Identical framing semantics to `feed`+`next_frame`.
    pub fn feed_all(&mut self, bytes: &[u8], out: &mut Vec<Vec<u8>>) -> Result<()> {
        // a large frame mid-fill on the read-into path absorbs its
        // bytes first, then drain frames already completed in the
        // buffer (covers callers mixing ingest styles); afterwards
        // anything buffered is strictly a partial frame
        let mut rest = self.fill_direct(bytes);
        while let Some(f) = self.next_frame()? {
            out.push(f);
        }
        // finish the buffered partial frame first (rare): hand over only
        // the bytes it still needs, then fall through
        while !rest.is_empty() && self.buffered_in() > 0 {
            let take = self.bytes_to_boundary().min(rest.len());
            if let Some(f) = self.feed(&rest[..take])? {
                out.push(f);
            }
            rest = &rest[take..];
        }
        // hot path: whole frames straight out of the input slice
        while rest.len() >= FRAME_HEADER {
            let n =
                u32::from_le_bytes(rest[..FRAME_HEADER].try_into().unwrap()) as usize;
            ensure!(n <= MAX_FRAME, "frame length {n} exceeds limit");
            if rest.len() < FRAME_HEADER + n {
                break;
            }
            out.push(rest[FRAME_HEADER..FRAME_HEADER + n].to_vec());
            self.frames_in += 1;
            rest = &rest[FRAME_HEADER + n..];
        }
        // incomplete tail: buffer for the next read.  A tail with a
        // visible length prefix was already validated by the loop above;
        // in_buf is empty and compacted whenever control reaches here.
        if !rest.is_empty() {
            self.in_buf.extend_from_slice(rest);
        }
        Ok(())
    }

    /// Writable slice for single-copy socket reads (reserve then fill).
    /// Once the length prefix of a frame with ≥ [`DIRECT_READ_MIN`]
    /// body bytes is buffered, the codec allocates the frame's own
    /// buffer, moves the already-received prefix into it, and hands out
    /// the unfilled tail — the caller reads from the fd straight into
    /// the frame's final home and acknowledges with [`Self::commit`];
    /// the completed frame surfaces through [`Self::next_frame`].
    ///
    /// Returns `None` while the stream is between large frames: headers
    /// and small frames take the buffered `feed`/`feed_all` path, where
    /// one scratch read ingests many frames per syscall.  Complete
    /// buffered frames must be drained before a slot is offered, and a
    /// poisoned length prefix is never allocated for — it keeps failing
    /// through `next_frame`.
    pub fn read_slot(&mut self) -> Option<&mut [u8]> {
        if self.direct.is_none() {
            let avail = self.in_buf.len() - self.in_pos;
            if avail < FRAME_HEADER {
                return None;
            }
            let len: [u8; FRAME_HEADER] =
                self.in_buf[self.in_pos..self.in_pos + FRAME_HEADER].try_into().unwrap();
            let n = u32::from_le_bytes(len) as usize;
            if n < DIRECT_READ_MIN || n > MAX_FRAME || avail >= FRAME_HEADER + n {
                return None;
            }
            // the incomplete frame is by construction the only pending
            // content: move its body prefix (bounded by one read's
            // worth of bytes) into the frame's own buffer.  `vec![0; n]`
            // is an alloc_zeroed — for large n that is freshly mapped
            // zero pages, not a memset pass.
            let mut buf = vec![0u8; n];
            let body = avail - FRAME_HEADER;
            buf[..body].copy_from_slice(&self.in_buf[self.in_pos + FRAME_HEADER..]);
            self.in_pos = 0;
            if self.in_buf.capacity() > RETAIN_CAP {
                self.in_buf = Vec::new();
            } else {
                self.in_buf.clear();
            }
            self.direct = Some(DirectFrame { buf, filled: body });
        }
        let d = self.direct.as_mut().unwrap();
        Some(&mut d.buf[d.filled..])
    }

    /// Acknowledge `n` bytes read into the slice from
    /// [`Self::read_slot`].  Panics if `n` overruns the slot or no slot
    /// was reserved — both are caller bugs, not wire conditions.
    pub fn commit(&mut self, n: usize) {
        let d = self.direct.as_mut().expect("commit without a read_slot");
        assert!(d.filled + n <= d.buf.len(), "committed past the reserved slot");
        d.filled += n;
        if d.filled == d.buf.len() {
            self.finish_direct();
        }
    }

    /// Route bytes into an in-progress direct frame (the mixing path
    /// for callers interleaving `feed`/`feed_all` with `read_slot`);
    /// returns whatever is left once the frame is satisfied.
    fn fill_direct<'a>(&mut self, bytes: &'a [u8]) -> &'a [u8] {
        let Some(d) = self.direct.as_mut() else { return bytes };
        let take = (d.buf.len() - d.filled).min(bytes.len());
        d.buf[d.filled..d.filled + take].copy_from_slice(&bytes[..take]);
        d.filled += take;
        if d.filled == d.buf.len() {
            self.finish_direct();
        }
        &bytes[take..]
    }

    fn finish_direct(&mut self) {
        if let Some(d) = self.direct.take() {
            self.frames_in += 1;
            self.ready.push_back(d.buf);
        }
    }

    /// How many more bytes the *pending* partial frame needs before a
    /// frame boundary decision can advance: the rest of the length
    /// prefix, the rest of the announced body, or the unfilled tail of
    /// a direct-mode frame.
    fn bytes_to_boundary(&self) -> usize {
        if let Some(d) = &self.direct {
            return (d.buf.len() - d.filled).max(1);
        }
        let have = self.in_buf.len() - self.in_pos;
        if have < FRAME_HEADER {
            return FRAME_HEADER - have;
        }
        let len: [u8; FRAME_HEADER] =
            self.in_buf[self.in_pos..self.in_pos + FRAME_HEADER].try_into().unwrap();
        // the prefix was validated against MAX_FRAME when it became
        // visible; `.max(1)` keeps callers' take-loops finite even if
        // the partial-frame invariant were ever violated
        (FRAME_HEADER + u32::from_le_bytes(len) as usize).saturating_sub(have).max(1)
    }

    /// Bytes buffered on the read side that do not yet form a frame
    /// (including the header + filled tail of a direct-mode frame).
    pub fn buffered_in(&self) -> usize {
        (self.in_buf.len() - self.in_pos)
            + self.direct.as_ref().map_or(0, |d| FRAME_HEADER + d.filled)
    }

    // -- write half ---------------------------------------------------------

    /// Queue `payload` as one length-prefixed frame.  Prefix and payload
    /// are contiguous in the write buffer, so the caller's next `write`
    /// can cover both in a single syscall.
    pub fn enqueue_frame(&mut self, payload: &[u8]) -> Result<()> {
        ensure!(payload.len() <= MAX_FRAME, "frame too large: {}", payload.len());
        if self.out_pos == self.out_buf.len() {
            self.out_pos = 0;
            if self.out_buf.capacity() > RETAIN_CAP {
                self.out_buf = Vec::new();
            } else {
                self.out_buf.clear();
            }
        } else if self.out_pos > 64 * 1024 {
            // long-lived partially-flushed queues: reclaim the flushed
            // prefix so the buffer tracks the backlog, not the history
            self.out_buf.drain(..self.out_pos);
            self.out_pos = 0;
        }
        self.out_buf.extend_from_slice(&frame_prefix(payload.len()));
        self.out_buf.extend_from_slice(payload);
        self.frames_out += 1;
        self.payload_bytes_out += payload.len() as u64;
        Ok(())
    }

    /// Queue raw bytes with **no frame header** — the escape hatch for
    /// the reactor's `/metrics` path, whose response is an HTTP/1.0
    /// document read by curl/Prometheus, not a framed peer.  Reuses the
    /// same write queue, so flushing, backpressure accounting, and the
    /// drain-then-close machinery all apply unchanged.  Not counted in
    /// `frames_out`/`payload_bytes_out`: those meter protocol frames.
    pub fn enqueue_raw(&mut self, bytes: &[u8]) {
        if self.out_pos == self.out_buf.len() {
            self.out_pos = 0;
            if self.out_buf.capacity() > RETAIN_CAP {
                self.out_buf = Vec::new();
            } else {
                self.out_buf.clear();
            }
        }
        self.out_buf.extend_from_slice(bytes);
    }

    /// Queued wire bytes not yet written to the socket.
    pub fn writable_bytes(&self) -> &[u8] {
        &self.out_buf[self.out_pos..]
    }

    /// Acknowledge that the first `n` bytes of [`Self::writable_bytes`]
    /// reached the socket.
    pub fn consume_written(&mut self, n: usize) {
        debug_assert!(self.out_pos + n <= self.out_buf.len(), "consumed more than queued");
        self.out_pos = (self.out_pos + n).min(self.out_buf.len());
    }

    /// Unflushed wire bytes — the reactor's slow-reader signal.
    pub fn pending_out(&self) -> usize {
        self.out_buf.len() - self.out_pos
    }

    // -- counters -----------------------------------------------------------

    pub fn frames_decoded(&self) -> u64 {
        self.frames_in
    }

    pub fn frames_enqueued(&self) -> u64 {
        self.frames_out
    }

    /// Payload bytes enqueued so far (framing prefix excluded).
    pub fn payload_bytes_enqueued(&self) -> u64 {
        self.payload_bytes_out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wire(frames: &[&[u8]]) -> Vec<u8> {
        let mut w = Vec::new();
        for f in frames {
            w.extend_from_slice(&encode_frame(f));
        }
        w
    }

    fn drain(c: &mut FrameCodec, first: Option<Vec<u8>>) -> Vec<Vec<u8>> {
        let mut got = Vec::new();
        let mut cur = first;
        while let Some(f) = cur {
            got.push(f);
            cur = c.next_frame().unwrap();
        }
        got
    }

    #[test]
    fn one_feed_many_frames() {
        let mut c = FrameCodec::new();
        let first = c.feed(&wire(&[b"alpha".as_slice(), b"", b"gamma"])).unwrap();
        let got = drain(&mut c, first);
        assert_eq!(got, vec![b"alpha".to_vec(), Vec::new(), b"gamma".to_vec()]);
        assert_eq!(c.frames_decoded(), 3);
        assert_eq!(c.buffered_in(), 0);
    }

    #[test]
    fn byte_at_a_time_preserves_frames() {
        let frames: Vec<&[u8]> = vec![b"x".as_slice(), b"a longer frame payload", b""];
        let w = wire(&frames);
        let mut c = FrameCodec::new();
        let mut got = Vec::new();
        for b in &w {
            let first = c.feed(std::slice::from_ref(b)).unwrap();
            got.extend(drain(&mut c, first));
        }
        let want: Vec<Vec<u8>> = frames.iter().map(|f| f.to_vec()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn partial_frame_survives_across_feeds() {
        let w = wire(&[b"split across reads"]);
        let mut c = FrameCodec::new();
        let (a, b) = w.split_at(7);
        assert!(c.feed(a).unwrap().is_none());
        assert_eq!(c.buffered_in(), 7);
        let f = c.feed(b).unwrap().expect("frame completes");
        assert_eq!(f, b"split across reads");
    }

    #[test]
    fn oversized_length_rejected_before_body_arrives() {
        let mut c = FrameCodec::new();
        // only the poisoned prefix, no body: must already error
        let bad = ((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(c.feed(&bad).is_err());
    }

    #[test]
    fn max_frame_boundary_accepted() {
        let mut c = FrameCodec::new();
        let payload = vec![7u8; 1024];
        let f = c.feed(&encode_frame(&payload)).unwrap().unwrap();
        assert_eq!(f, payload);
        assert!(c.enqueue_frame(&vec![0u8; MAX_FRAME + 1]).is_err());
    }

    #[test]
    fn write_half_roundtrips_through_read_half() {
        let mut w = FrameCodec::new();
        w.enqueue_frame(b"first").unwrap();
        w.enqueue_frame(b"second frame").unwrap();
        assert_eq!(w.frames_enqueued(), 2);
        assert_eq!(w.payload_bytes_enqueued(), (5 + 12) as u64);
        assert_eq!(w.pending_out(), 5 + 12 + 2 * FRAME_HEADER);

        // drain the wire bytes in awkward chunks into a reader codec
        let mut r = FrameCodec::new();
        let mut got = Vec::new();
        while w.pending_out() > 0 {
            let chunk: Vec<u8> = w.writable_bytes().iter().take(3).copied().collect();
            w.consume_written(chunk.len());
            let first = r.feed(&chunk).unwrap();
            got.extend(drain(&mut r, first));
        }
        assert_eq!(got, vec![b"first".to_vec(), b"second frame".to_vec()]);
    }

    #[test]
    fn consume_written_partial_then_rest() {
        let mut c = FrameCodec::new();
        c.enqueue_frame(b"payload").unwrap();
        let total = c.pending_out();
        c.consume_written(3);
        assert_eq!(c.pending_out(), total - 3);
        let rest = c.writable_bytes().len();
        c.consume_written(rest);
        assert_eq!(c.pending_out(), 0);
        // a fresh enqueue reuses the drained buffer
        c.enqueue_frame(b"x").unwrap();
        assert_eq!(c.pending_out(), FRAME_HEADER + 1);
    }

    #[test]
    fn enqueue_raw_skips_framing_and_counters() {
        let mut c = FrameCodec::new();
        c.enqueue_raw(b"HTTP/1.0 200 OK\r\n\r\n");
        assert_eq!(c.writable_bytes(), b"HTTP/1.0 200 OK\r\n\r\n");
        assert_eq!(c.frames_enqueued(), 0, "raw bytes are not protocol frames");
        let n = c.pending_out();
        c.consume_written(n);
        assert_eq!(c.pending_out(), 0);
        // raw and framed writes share one queue, in order
        c.enqueue_raw(b"raw");
        c.enqueue_frame(b"framed").unwrap();
        let mut want = b"raw".to_vec();
        want.extend_from_slice(&encode_frame(b"framed"));
        assert_eq!(c.writable_bytes(), &want[..]);
    }

    #[test]
    fn feed_all_handles_partial_boundaries() {
        let mut w = Vec::new();
        w.extend_from_slice(&encode_frame(b"first"));
        w.extend_from_slice(&encode_frame(b"second frame"));
        w.extend_from_slice(&encode_frame(b"third"));
        let mut c = FrameCodec::new();
        let mut out = Vec::new();
        // split mid-header of frame 2, then mid-body of frame 3
        c.feed_all(&w[..11], &mut out).unwrap();
        c.feed_all(&w[11..30], &mut out).unwrap();
        c.feed_all(&w[30..], &mut out).unwrap();
        assert_eq!(
            out,
            vec![b"first".to_vec(), b"second frame".to_vec(), b"third".to_vec()]
        );
        assert_eq!(c.buffered_in(), 0);
        assert_eq!(c.frames_decoded(), 3);
    }

    #[test]
    fn feed_all_rejects_oversize_prefix_in_tail() {
        let mut c = FrameCodec::new();
        let mut out = Vec::new();
        let mut w = encode_frame(b"ok");
        w.extend_from_slice(&((MAX_FRAME + 1) as u32).to_le_bytes());
        assert!(c.feed_all(&w, &mut out).is_err());
        assert_eq!(out, vec![b"ok".to_vec()], "good frames before the poison still land");
    }

    #[test]
    fn drained_buffers_release_oversized_capacity() {
        let mut c = FrameCodec::new();
        let big = vec![7u8; RETAIN_CAP + 4096];
        let f = c.feed(&encode_frame(&big)).unwrap().unwrap();
        assert_eq!(f.len(), big.len());
        assert!(
            c.in_buf.capacity() <= RETAIN_CAP,
            "drained read buffer retained {} bytes",
            c.in_buf.capacity()
        );
        c.enqueue_frame(&big).unwrap();
        let n = c.pending_out();
        c.consume_written(n);
        c.enqueue_frame(b"x").unwrap();
        assert!(
            c.out_buf.capacity() <= RETAIN_CAP,
            "drained write buffer retained {} bytes",
            c.out_buf.capacity()
        );
    }

    #[test]
    fn frame_wire_len_matches_encode_frame() {
        for n in [0usize, 1, 17, 4096] {
            assert_eq!(encode_frame(&vec![0u8; n]).len(), frame_wire_len(n));
        }
    }

    #[test]
    fn read_slot_fills_large_frame_in_place() {
        let payload: Vec<u8> = (0..DIRECT_READ_MIN * 3).map(|i| i as u8).collect();
        let wire = encode_frame(&payload);
        let mut c = FrameCodec::new();
        // the header (+ a small body prefix) arrives via the buffered path
        assert!(c.feed(&wire[..100]).unwrap().is_none());
        // from here the codec offers the frame's own unfilled tail
        let mut i = 100;
        while i < wire.len() {
            let slot = c.read_slot().expect("large partial frame offers a slot");
            let k = slot.len().min(777).min(wire.len() - i);
            slot[..k].copy_from_slice(&wire[i..i + k]);
            c.commit(k);
            i += k;
        }
        let f = c.next_frame().unwrap().expect("frame completes");
        assert_eq!(f, payload);
        assert_eq!(c.buffered_in(), 0);
        assert_eq!(c.frames_decoded(), 1);
        assert!(c.read_slot().is_none(), "no slot between frames");
    }

    #[test]
    fn read_slot_not_offered_for_small_frames() {
        let wire = encode_frame(&vec![3u8; DIRECT_READ_MIN - 1]);
        let mut c = FrameCodec::new();
        assert!(c.feed(&wire[..16]).unwrap().is_none());
        assert!(c.read_slot().is_none(), "sub-threshold bodies stay buffered");
        let f = c.feed(&wire[16..]).unwrap().expect("frame completes via feed");
        assert_eq!(f.len(), DIRECT_READ_MIN - 1);
    }

    #[test]
    fn feed_completes_a_direct_frame_and_keeps_order() {
        let big: Vec<u8> = (0..DIRECT_READ_MIN + 64).map(|i| (i * 7) as u8).collect();
        let mut wire = encode_frame(&big);
        wire.extend_from_slice(&encode_frame(b"after"));
        let mut c = FrameCodec::new();
        assert!(c.feed(&wire[..FRAME_HEADER + 8]).unwrap().is_none());
        let slot = c.read_slot().expect("direct slot");
        let k = slot.len().min(32);
        slot[..k].copy_from_slice(&wire[FRAME_HEADER + 8..FRAME_HEADER + 8 + k]);
        c.commit(k);
        // the rest (direct tail + the following frame) arrives via feed:
        // the direct frame must pop first, then the small one
        let first = c.feed(&wire[FRAME_HEADER + 8 + k..]).unwrap().expect("big frame");
        assert_eq!(first, big);
        assert_eq!(c.next_frame().unwrap().unwrap(), b"after");
        assert_eq!(c.frames_decoded(), 2);
        assert_eq!(c.buffered_in(), 0);
    }
}
