//! Listener provisioning and admission syscalls for the reactor fleet:
//! how N reactor shards come to own N accept paths, and how one socket
//! is accepted with the fewest syscalls the platform allows.
//!
//! Two fleet shapes ([`bind_shard_listeners`] / [`share_listener`]):
//!
//! * **Per-shard `SO_REUSEPORT` listeners** (Linux, and only when this
//!   module does the binding): every shard binds its *own* listener to
//!   the same address with `SO_REUSEPORT` set **before** `bind(2)`, so
//!   all of them join one kernel reuseport group and incoming
//!   connections are spread across shards by the kernel's 4-tuple hash
//!   — no accept lock, no shared accept queue, no thundering herd.
//!   The flag must be present at bind time on *every* member for the
//!   group to form correctly, which is why this shape is only offered
//!   when the fleet binds its listeners itself
//!   ([`crate::coordinator::cloud::CloudServer::bind`]); a listener
//!   bound elsewhere cannot be retrofitted into a balanced group.
//! * **Shared accept queue** (fallback everywhere): one listener's fd is
//!   dup'd into every shard's event set ([`TcpListener::try_clone`]).
//!   All shards race `accept` on the same kernel queue; losers observe
//!   `WouldBlock` and move on.  Strictly correct on every platform —
//!   the herd costs a few spurious wakes under connection bursts, which
//!   is the price of a caller-provided listener.
//!
//! Admission ([`accept_nonblocking`]): on Linux one
//! `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)` yields a connection that is
//! already nonblocking — the fcntl round trips the portable
//! `accept` + `set_nonblocking` pair pays per admitted socket are gone.
//! The portable pair is kept as [`accept_portable`] (compiled and
//! unit-tested on every platform, including Linux, so the fallback leg
//! cannot rot).
//!
//! Everything here is declared straight against the platform libc — the
//! same no-new-crate discipline as [`crate::net::event`].

use std::io;
use std::net::{TcpListener, TcpStream};

/// How a fleet's listeners were provisioned (reported through
/// `ReactorStats::accept_mode`).
pub const MODE_REUSEPORT: &str = "reuseport";
/// All shards share one dup'd accept queue.
pub const MODE_SHARED: &str = "shared";
/// A single shard owns the single listener (no sharing needed).
pub const MODE_SINGLE: &str = "single";
/// No listener at all: connections arrive via `ReactorHandle::register`.
pub const MODE_NONE: &str = "none";

#[cfg(target_os = "linux")]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const AF_INET: c_int = 2;
    pub const AF_INET6: c_int = 10;
    pub const SOCK_STREAM: c_int = 1;
    // x86_64 / aarch64 values (the targets this tree builds for); both
    // flags were introduced in 2.6.27
    pub const SOCK_NONBLOCK: c_int = 0o4000;
    pub const SOCK_CLOEXEC: c_int = 0o2000000;
    pub const SOL_SOCKET: c_int = 1;
    pub const SO_REUSEADDR: c_int = 2;
    pub const SO_REUSEPORT: c_int = 15;

    extern "C" {
        pub fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        pub fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *const c_void,
            len: u32,
        ) -> c_int;
        pub fn bind(fd: c_int, addr: *const c_void, len: u32) -> c_int;
        pub fn listen(fd: c_int, backlog: c_int) -> c_int;
        pub fn accept4(fd: c_int, addr: *mut c_void, len: *mut u32, flags: c_int) -> c_int;
    }
}

/// Bind one listener per shard at `addr`.  Returns the accept mode plus
/// exactly `shards` listener slots (index = shard).  On Linux with more
/// than one shard this binds a true `SO_REUSEPORT` fleet; if that fails
/// (exotic kernel, permissions) — or off Linux — every shard shares one
/// accept queue instead, so the fleet always comes up.
pub fn bind_shard_listeners(
    addr: &str,
    shards: usize,
) -> io::Result<(&'static str, Vec<Option<TcpListener>>)> {
    if shards <= 1 {
        return Ok((MODE_SINGLE, vec![Some(TcpListener::bind(addr)?)]));
    }
    #[cfg(target_os = "linux")]
    {
        match bind_reuseport_fleet(addr, shards) {
            Ok(fleet) => {
                return Ok((MODE_REUSEPORT, fleet.into_iter().map(Some).collect()));
            }
            Err(e) => log::warn!(
                "SO_REUSEPORT listener fleet unavailable ({e}); \
                 shards will share one accept queue"
            ),
        }
    }
    Ok(share_listener(TcpListener::bind(addr)?, shards))
}

/// Spread one already-bound listener across `shards` shards by dup'ing
/// its fd: every shard registers the same accept queue and races
/// `accept` (losers see `WouldBlock`).  A dup failure leaves that shard
/// with no listener — it still serves connections handed to it via
/// `ReactorHandle::register`.
pub fn share_listener(
    listener: TcpListener,
    shards: usize,
) -> (&'static str, Vec<Option<TcpListener>>) {
    if shards <= 1 {
        return (MODE_SINGLE, vec![Some(listener)]);
    }
    let mut out: Vec<Option<TcpListener>> = Vec::with_capacity(shards);
    for shard in 1..shards {
        match listener.try_clone() {
            Ok(dup) => out.push(Some(dup)),
            Err(e) => {
                log::warn!("cannot dup listener for reactor shard {shard}: {e}");
                out.push(None);
            }
        }
    }
    out.insert(0, Some(listener));
    (MODE_SHARED, out)
}

/// Accept one pending connection, nonblocking from birth.  Linux:
/// a single `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)` — no per-accept
/// fcntl round trips.  Elsewhere: [`accept_portable`].
#[cfg(target_os = "linux")]
pub fn accept_nonblocking(listener: &TcpListener) -> io::Result<TcpStream> {
    use std::os::fd::{AsRawFd, FromRawFd};
    let fd = unsafe {
        sys::accept4(
            listener.as_raw_fd(),
            std::ptr::null_mut(),
            std::ptr::null_mut(),
            sys::SOCK_NONBLOCK | sys::SOCK_CLOEXEC,
        )
    };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(unsafe { TcpStream::from_raw_fd(fd) })
}

#[cfg(not(target_os = "linux"))]
pub fn accept_nonblocking(listener: &TcpListener) -> io::Result<TcpStream> {
    accept_portable(listener)
}

/// The portable accept path: `accept(2)` then an explicit
/// `set_nonblocking`.  Compiled on every platform (Linux included) so
/// the non-`accept4` leg stays exercised by the test suite.
pub fn accept_portable(listener: &TcpListener) -> io::Result<TcpStream> {
    let (stream, _) = listener.accept()?;
    stream.set_nonblocking(true)?;
    Ok(stream)
}

/// Bind `n` fresh `SO_REUSEPORT` listeners to `addr` (the first resolves
/// an ephemeral port for the rest).  All-or-nothing: any failure closes
/// what was bound and reports the error so the caller can fall back.
#[cfg(target_os = "linux")]
fn bind_reuseport_fleet(addr: &str, n: usize) -> io::Result<Vec<TcpListener>> {
    use std::net::ToSocketAddrs;
    let sa = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "address resolved to nothing"))?;
    let first = bind_reuseport(sa)?;
    // with port 0 the kernel picked one; every other member binds to it
    let concrete = first.local_addr()?;
    let mut fleet = Vec::with_capacity(n);
    fleet.push(first);
    for _ in 1..n {
        fleet.push(bind_reuseport(concrete)?);
    }
    Ok(fleet)
}

/// One `SO_REUSEPORT` listener: socket → REUSEADDR + REUSEPORT (both
/// **before** bind, which is what admits it into the reuseport group) →
/// bind → listen.  The fd is owned from creation, so every error path
/// closes it.
#[cfg(target_os = "linux")]
fn bind_reuseport(addr: std::net::SocketAddr) -> io::Result<TcpListener> {
    use std::os::fd::{AsRawFd, FromRawFd, OwnedFd};
    let (family, sa, sa_len) = sockaddr_bytes(&addr);
    let fd = unsafe { sys::socket(family, sys::SOCK_STREAM | sys::SOCK_CLOEXEC, 0) };
    if fd < 0 {
        return Err(io::Error::last_os_error());
    }
    let owned = unsafe { OwnedFd::from_raw_fd(fd) };
    set_sockopt_one(owned.as_raw_fd(), sys::SO_REUSEADDR)?;
    set_sockopt_one(owned.as_raw_fd(), sys::SO_REUSEPORT)?;
    if unsafe { sys::bind(owned.as_raw_fd(), sa.as_ptr() as *const _, sa_len) } != 0 {
        return Err(io::Error::last_os_error());
    }
    if unsafe { sys::listen(owned.as_raw_fd(), 1024) } != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(TcpListener::from(owned))
}

#[cfg(target_os = "linux")]
fn set_sockopt_one(fd: std::os::raw::c_int, opt: std::os::raw::c_int) -> io::Result<()> {
    let one: std::os::raw::c_int = 1;
    let rc = unsafe {
        sys::setsockopt(
            fd,
            sys::SOL_SOCKET,
            opt,
            &one as *const std::os::raw::c_int as *const _,
            std::mem::size_of::<std::os::raw::c_int>() as u32,
        )
    };
    if rc != 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// Serialize a `SocketAddr` into the raw `sockaddr_in` / `sockaddr_in6`
/// layout `bind(2)` expects.  Returned buffer is sized for the larger
/// v6 form; the length says how much of it is live.
#[cfg(target_os = "linux")]
fn sockaddr_bytes(addr: &std::net::SocketAddr) -> (std::os::raw::c_int, [u8; 28], u32) {
    let mut buf = [0u8; 28];
    match addr {
        std::net::SocketAddr::V4(a) => {
            buf[0..2].copy_from_slice(&(sys::AF_INET as u16).to_ne_bytes());
            buf[2..4].copy_from_slice(&a.port().to_be_bytes());
            buf[4..8].copy_from_slice(&a.ip().octets());
            (sys::AF_INET, buf, 16)
        }
        std::net::SocketAddr::V6(a) => {
            buf[0..2].copy_from_slice(&(sys::AF_INET6 as u16).to_ne_bytes());
            buf[2..4].copy_from_slice(&a.port().to_be_bytes());
            buf[4..8].copy_from_slice(&a.flowinfo().to_be_bytes());
            buf[8..24].copy_from_slice(&a.ip().octets());
            buf[24..28].copy_from_slice(&a.scope_id().to_ne_bytes());
            (sys::AF_INET6, buf, 28)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    /// The portable accept leg stays exercised on Linux too: accepted
    /// sockets come back nonblocking and wired to the right peer.
    #[test]
    fn accept_portable_yields_nonblocking_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let mut server = accept_portable(&listener).unwrap();
        // nonblocking: a read with nothing pending is WouldBlock, not a hang
        let mut buf = [0u8; 8];
        match server.read(&mut buf) {
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::WouldBlock),
            Ok(n) => panic!("read of an empty socket returned {n} bytes"),
        }
        client.write_all(b"ping").unwrap();
        client.flush().unwrap();
        let mut got = 0usize;
        for _ in 0..200 {
            match server.read(&mut buf[got..]) {
                Ok(n) => {
                    got += n;
                    if got >= 4 {
                        break;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(1))
                }
                Err(e) => panic!("read failed: {e}"),
            }
        }
        assert_eq!(&buf[..4], b"ping");
    }

    /// Same contract for the platform-default admission path (accept4 on
    /// Linux): nonblocking from birth.
    #[test]
    fn accept_nonblocking_yields_nonblocking_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let mut server = accept_nonblocking(&listener).unwrap();
        let mut buf = [0u8; 8];
        match server.read(&mut buf) {
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::WouldBlock),
            Ok(n) => panic!("read of an empty socket returned {n} bytes"),
        }
    }

    #[test]
    fn share_listener_duplicates_one_accept_queue() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (mode, slots) = share_listener(listener, 3);
        assert_eq!(mode, MODE_SHARED);
        assert_eq!(slots.len(), 3);
        // every dup answers for the same port
        for slot in &slots {
            assert_eq!(slot.as_ref().unwrap().local_addr().unwrap(), addr);
        }
        // a connection through the shared queue is acceptable from any dup
        let _client = TcpStream::connect(addr).unwrap();
        let accepted = slots
            .iter()
            .any(|slot| accept_portable(slot.as_ref().unwrap()).is_ok());
        assert!(accepted, "no dup of the shared listener could accept");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn reuseport_fleet_binds_one_port_and_serves_from_any_member() {
        let (mode, slots) = bind_shard_listeners("127.0.0.1:0", 4).unwrap();
        assert_eq!(mode, MODE_REUSEPORT, "linux must get the reuseport fleet");
        assert_eq!(slots.len(), 4);
        let addr = slots[0].as_ref().unwrap().local_addr().unwrap();
        for slot in &slots {
            let l = slot.as_ref().unwrap();
            l.set_nonblocking(true).unwrap();
            assert_eq!(l.local_addr().unwrap(), addr, "fleet spans one port");
        }
        // the kernel hashes each connection to exactly one member; with
        // several connections, every one must be acceptable by exactly
        // one listener of the group
        let clients: Vec<TcpStream> =
            (0..16).map(|_| TcpStream::connect(addr).unwrap()).collect();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut accepted = 0usize;
        for slot in &slots {
            let l = slot.as_ref().unwrap();
            loop {
                match accept_nonblocking(l) {
                    Ok(_) => accepted += 1,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) => panic!("accept failed: {e}"),
                }
            }
        }
        assert_eq!(accepted, clients.len(), "every connection lands on exactly one member");
    }
}
