//! Networking: the sans-I/O wire layer and WAN models.
//!
//! The wire stack is layered so the protocol framing exists **exactly
//! once** and every I/O strategy adapts around it:
//!
//! * [`codec`] — [`codec::FrameCodec`], the sans-I/O framing core.  It
//!   performs no I/O: callers push received bytes in (`feed` /
//!   `next_frame`) and drain queued wire bytes out (`enqueue_frame` /
//!   `writable_bytes` / `consume_written`), with `MAX_FRAME` enforced
//!   mid-stream and backpressure visible via `pending_out`.
//! * [`reactor`] — the cloud side: one event-driven thread
//!   ([`reactor::Reactor`], `poll(2)`-based) owns every accepted socket,
//!   decodes frames in place (zero-copy upload path), routes work to the
//!   scheduler's workers, and drains token responses through
//!   per-connection write queues with slow-reader eviction and
//!   worker-queue backpressure.
//! * [`transport`] — the blocking adapters: [`transport::TcpTransport`]
//!   (edge client side), [`transport::InProcTransport`] (tests), and the
//!   [`transport::Throttled`] WAN wrapper, all wrapping the same codec.
//! * [`profiles`], [`simulated`] — WAN link profiles and the analytic
//!   link model used by the DES harness (which prices messages with
//!   [`codec::frame_wire_len`], so simulated wire costs track the real
//!   framing).
pub mod codec;
pub mod profiles;
pub mod reactor;
pub mod simulated;
pub mod transport;
