//! Networking: the sans-I/O wire layer and WAN models.
//!
//! The wire stack is layered so the protocol framing exists **exactly
//! once**, readiness exists exactly once, and every I/O strategy adapts
//! around them:
//!
//! * [`codec`] — [`codec::FrameCodec`], the sans-I/O framing core.  It
//!   performs no I/O: callers push received bytes in (`feed` /
//!   `next_frame` / bulk `feed_all`) and drain queued wire bytes out
//!   (`enqueue_frame` / `writable_bytes` / `consume_written`), with
//!   `MAX_FRAME` enforced mid-stream and backpressure visible via
//!   `pending_out`.  Large frame bodies use the reserve-then-fill
//!   single-copy path (`read_slot` / `commit`): the codec hands out a
//!   writable slice sized from the decoded length prefix and the caller
//!   reads from the fd straight into the frame's final buffer.
//! * [`event`] — [`event::EventSet`], the readiness abstraction: an
//!   edge-triggered `epoll(7)` backend on Linux (O(1) interest changes,
//!   O(ready) wakes) and a portable `poll(2)` fallback, both declared
//!   straight against the platform libc (no new crate), selected at
//!   runtime (`ReactorConfig::backend` / `CE_REACTOR_BACKEND`).  It
//!   knows nothing about frames or connections — only fds, tokens, and
//!   interest.
//! * [`listener`] — accept-path provisioning: per-shard `SO_REUSEPORT`
//!   listeners on Linux (bound flag-first so every member joins the
//!   kernel's load-balancing group), dup'd shared-accept-queue fallback
//!   elsewhere or for caller-bound listeners, and the
//!   `accept4(SOCK_NONBLOCK | SOCK_CLOEXEC)` admission helper with its
//!   portable `accept` + `set_nonblocking` twin.  Raw libc, no new
//!   crate; it knows nothing about events or frames — only how
//!   listeners come to exist and how sockets leave them.
//! * [`reactor`] — the cloud side: a fleet of event-driven shard
//!   threads ([`reactor::Reactor`], `ReactorConfig::shards`, default
//!   `min(4, cores)`).  Each shard owns its own `EventSet`, its own
//!   connection table and write queues, and its own accept path
//!   (accepting happens inside each shard's wake loop, so the cloud's
//!   thread budget is exactly `workers + shards`), decodes frames
//!   through the shared codec (zero-copy upload path, single-copy
//!   large-frame ingest), routes work to the scheduler's workers, and
//!   drains token responses through per-connection write queues with
//!   slow-reader eviction and worker-queue backpressure expressed as
//!   O(1) interest changes.  Connection ids are shard-tagged, so
//!   completions resolve to the owning shard and dead-conn fencing
//!   holds across the fleet.
//! * [`transport`] — the blocking adapters: [`transport::TcpTransport`]
//!   (edge client side), [`transport::InProcTransport`] (tests), and the
//!   [`transport::Throttled`] WAN wrapper, all wrapping the same codec.
//! * [`fault`] — deterministic fault injection, one layer above
//!   [`transport`] and orthogonal to it: [`fault::FaultTransport`]
//!   wraps any `Transport` (the same adapter shape as `Throttled`) and
//!   executes a scripted [`fault::FaultPlan`] — sever/drop/delay/
//!   black-hole at the Nth frame, keyed by frame ordinal so every
//!   failure lands at the same protocol step on every run — while
//!   [`fault::ReactorFault`] is the cloud-side twin the reactor applies
//!   per connection (`CE_FAULT` env / `ReactorConfig::fault`).  It
//!   knows nothing about framing or readiness — only which frame
//!   ordinal dies and how.
//! * [`profiles`], [`simulated`] — WAN link profiles and the analytic
//!   link model used by the DES harness (which prices messages with
//!   [`codec::frame_wire_len`], so simulated wire costs track the real
//!   framing).
pub mod codec;
pub mod event;
pub mod fault;
pub mod listener;
pub mod profiles;
pub mod reactor;
pub mod simulated;
pub mod transport;
