//! Transports and WAN models.
pub mod profiles;
pub mod simulated;
pub mod transport;
