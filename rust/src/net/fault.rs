//! Deterministic fault injection for the wire layer.
//!
//! Two hooks, one per side of the link:
//!
//! * [`FaultTransport`] wraps any blocking [`Transport`] on the edge
//!   side (same shape as [`Throttled`](super::transport::Throttled))
//!   and applies a scripted [`FaultPlan`]: sever, drop, delay, or
//!   black-hole the Nth frame in either direction.  Frame ordinals —
//!   not wall-clock time — key the schedule, so a fault lands at
//!   exactly the same protocol step on every run.
//! * [`ReactorFault`] is the cloud-side hook: the reactor closes a
//!   connection right after its Nth inbound frame, which from the
//!   edge's point of view is a server that restarted or a NAT that
//!   expired mid-run.  It is carried on `ReactorConfig` and, when left
//!   unset, resolved from the [`FAULT_ENV`] env var — the `CE_FAULT`
//!   CI leg runs the whole fault suite with every cloud connection
//!   being cut out from under the clients, and the reconnect path must
//!   keep every token stream bit-identical anyway.
//!
//! This module is also the seed of the ROADMAP's trace-level fault
//! injector: a recorded trace replayed through a `FaultPlan` reproduces
//! NAT expiry, mid-replay severs, and reconnect storms in-process.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::transport::Transport;
use crate::util::rng::Rng;

/// Env var consulted by [`ReactorFault::resolve`] when the reactor
/// config carries no explicit fault.  The spec is a comma-separated
/// list of clauses, all keyed by 0-based per-connection inbound frame
/// ordinals:
///
/// * `sever_in:<n>` — close the connection right after routing its
///   `n`-th inbound frame;
/// * `drop_in:<n>` — silently discard the `n`-th inbound frame (the
///   ordinal still advances);
/// * `delay_in:<n>:<ms>` — stall `ms` milliseconds before routing the
///   `n`-th inbound frame;
/// * `reorder_in:<n>:<k>` — hold the `n`-th inbound frame and deliver
///   it right after frame `n + k` routes (the ordinal still advances),
///   so the peer observes frames `n+1 .. n+k` arriving *before* frame
///   `n` — the out-of-order delivery a multipath middlebox produces.
///
/// e.g. `CE_FAULT=drop_in:3,sever_in:7`.
pub const FAULT_ENV: &str = "CE_FAULT";

/// What happens to one frame (or to the link from that frame on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Fail the operation and kill the transport: every later call
    /// errors too (the TCP-reset shape).
    Sever,
    /// Silently lose this one frame: a faulted send reports success, a
    /// faulted receive skips to the next frame.
    Drop,
    /// Hold the frame this long, then let it through.
    DelayMs(u64),
    /// From this frame on the link is a black hole (the NAT-expiry
    /// shape): sends are swallowed "successfully", deadline receives
    /// time out cleanly, and a blocking receive fails after a short
    /// grace sleep instead of hanging the caller forever.
    BlackHole,
}

/// A scripted fault schedule keyed by 0-based frame ordinal, one
/// ordinal space per direction.  Built either explicitly
/// (`sever_send_at(3)`) or from a seed ([`FaultPlan::seeded_sever`]);
/// both are pure data, so the same plan replays identically.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    on_send: BTreeMap<u64, Fault>,
    on_recv: BTreeMap<u64, Fault>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn sever_send_at(mut self, frame: u64) -> Self {
        self.on_send.insert(frame, Fault::Sever);
        self
    }

    pub fn sever_recv_at(mut self, frame: u64) -> Self {
        self.on_recv.insert(frame, Fault::Sever);
        self
    }

    pub fn drop_send_at(mut self, frame: u64) -> Self {
        self.on_send.insert(frame, Fault::Drop);
        self
    }

    pub fn drop_recv_at(mut self, frame: u64) -> Self {
        self.on_recv.insert(frame, Fault::Drop);
        self
    }

    pub fn delay_send_at(mut self, frame: u64, ms: u64) -> Self {
        self.on_send.insert(frame, Fault::DelayMs(ms));
        self
    }

    pub fn delay_recv_at(mut self, frame: u64, ms: u64) -> Self {
        self.on_recv.insert(frame, Fault::DelayMs(ms));
        self
    }

    pub fn black_hole_send_at(mut self, frame: u64) -> Self {
        self.on_send.insert(frame, Fault::BlackHole);
        self
    }

    pub fn black_hole_recv_at(mut self, frame: u64) -> Self {
        self.on_recv.insert(frame, Fault::BlackHole);
        self
    }

    /// A seeded single-sever plan: cuts the link at a pseudo-random
    /// frame ordinal in `[0, horizon)`, in a pseudo-random direction.
    /// Same seed, same plan — the reproducible way to scatter sever
    /// points across a test matrix without hand-picking each one.
    pub fn seeded_sever(seed: u64, horizon: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let frame = rng.gen_range(horizon.max(1) as usize) as u64;
        if rng.gen_bool(0.5) {
            Self::new().sever_send_at(frame)
        } else {
            Self::new().sever_recv_at(frame)
        }
    }

    pub fn is_empty(&self) -> bool {
        self.on_send.is_empty() && self.on_recv.is_empty()
    }

    fn send_fault(&self, frame: u64) -> Option<Fault> {
        self.on_send.get(&frame).copied()
    }

    fn recv_fault(&self, frame: u64) -> Option<Fault> {
        self.on_recv.get(&frame).copied()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LinkState {
    Alive,
    Severed,
    BlackHole,
}

/// A [`Transport`] wrapper that executes a [`FaultPlan`].  Ordinals
/// count frames actually consumed in each direction (a dropped frame
/// consumes its ordinal; a sever does not advance past it), so a plan
/// describes the exact protocol step where the failure lands.
pub struct FaultTransport<T: Transport> {
    inner: T,
    plan: FaultPlan,
    sent: u64,
    recvd: u64,
    state: LinkState,
}

impl<T: Transport> FaultTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        Self { inner, plan, sent: 0, recvd: 0, state: LinkState::Alive }
    }

    /// Frames let through (or dropped) in each direction so far.
    pub fn frames(&self) -> (u64, u64) {
        (self.sent, self.recvd)
    }

    fn check_alive(&self) -> Result<()> {
        match self.state {
            LinkState::Alive => Ok(()),
            LinkState::Severed => bail!("fault: link severed"),
            LinkState::BlackHole => bail!("fault: black hole"),
        }
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        match self.state {
            LinkState::Severed => bail!("fault: link severed"),
            // swallowed "successfully": the peer just never hears it
            LinkState::BlackHole => {
                self.sent += 1;
                return Ok(());
            }
            LinkState::Alive => {}
        }
        match self.plan.send_fault(self.sent) {
            Some(Fault::Sever) => {
                self.state = LinkState::Severed;
                bail!("fault: sever at send frame {}", self.sent)
            }
            Some(Fault::BlackHole) => {
                self.state = LinkState::BlackHole;
                self.sent += 1;
                Ok(())
            }
            Some(Fault::Drop) => {
                self.sent += 1;
                Ok(())
            }
            Some(Fault::DelayMs(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
                self.sent += 1;
                self.inner.send(frame)
            }
            None => {
                self.sent += 1;
                self.inner.send(frame)
            }
        }
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        loop {
            self.check_alive()?;
            match self.plan.recv_fault(self.recvd) {
                Some(Fault::Sever) => {
                    self.state = LinkState::Severed;
                    bail!("fault: sever at recv frame {}", self.recvd)
                }
                Some(Fault::BlackHole) => {
                    self.state = LinkState::BlackHole;
                    // grace sleep instead of hanging a blocking caller
                    std::thread::sleep(Duration::from_millis(10));
                    bail!("fault: black hole")
                }
                Some(Fault::Drop) => {
                    let _ = self.inner.recv()?;
                    self.recvd += 1;
                }
                Some(Fault::DelayMs(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                    let f = self.inner.recv()?;
                    self.recvd += 1;
                    return Ok(f);
                }
                None => {
                    let f = self.inner.recv()?;
                    self.recvd += 1;
                    return Ok(f);
                }
            }
        }
    }

    fn recv_deadline(&mut self, deadline: Instant) -> Result<Option<Vec<u8>>> {
        loop {
            match self.state {
                LinkState::Severed => bail!("fault: link severed"),
                // unreachable peer: the deadline passes with silence
                LinkState::BlackHole => {
                    let wait = deadline.saturating_duration_since(Instant::now());
                    std::thread::sleep(wait);
                    return Ok(None);
                }
                LinkState::Alive => {}
            }
            match self.plan.recv_fault(self.recvd) {
                Some(Fault::Sever) => {
                    self.state = LinkState::Severed;
                    bail!("fault: sever at recv frame {}", self.recvd)
                }
                Some(Fault::BlackHole) => {
                    self.state = LinkState::BlackHole;
                    // loop back into the black-hole arm above
                }
                Some(Fault::Drop) => match self.inner.recv_deadline(deadline)? {
                    Some(_) => self.recvd += 1,
                    None => return Ok(None),
                },
                Some(Fault::DelayMs(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                    let got = self.inner.recv_deadline(deadline)?;
                    if got.is_some() {
                        self.recvd += 1;
                    }
                    return Ok(got);
                }
                None => {
                    let got = self.inner.recv_deadline(deadline)?;
                    if got.is_some() {
                        self.recvd += 1;
                    }
                    return Ok(got);
                }
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent()
    }
}

/// Cloud-side fault hook, applied by every reactor shard to every
/// connection it owns.  All ordinals are 0-based per-connection inbound
/// frame counts — the same ordinal a recorded trace's `frame_in` events
/// carry, which is what lets [`crate::trace::anchored_fault`] turn a
/// recorded trace point back into one of these schedules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReactorFault {
    /// Close a connection right after its `n`-th inbound frame
    /// (`Some(0)` severs on the very first frame, the Hello).  From the
    /// edge it looks like a cloud restart: the next send or receive on
    /// that channel fails and the reconnect path takes over.
    pub sever_in_at: Option<u64>,
    /// Silently discard a connection's `n`-th inbound frame instead of
    /// routing it; the ordinal still advances (a lost frame was still
    /// received).  From the edge: an upload or request that vanished
    /// in flight over a live connection.
    pub drop_in_at: Option<u64>,
    /// Stall the shard [`ReactorFault::delay_in_ms`] milliseconds
    /// before routing a connection's `n`-th inbound frame — a slow
    /// middlebox, with the head-of-line blocking a real one causes.
    pub delay_in_at: Option<u64>,
    /// The stall applied at [`ReactorFault::delay_in_at`] (ignored when
    /// that is `None`).
    pub delay_in_ms: u64,
    /// Hold a connection's `n`-th inbound frame in a one-slot
    /// hold-and-release queue and route it right after frame
    /// `n + reorder_gap` routes — the peer sees the held frame arrive
    /// out of order.  A connection that closes before the release point
    /// silently loses the held frame (as a real reordering path would
    /// when the flow dies).
    pub reorder_in_at: Option<u64>,
    /// The gap applied at [`ReactorFault::reorder_in_at`]: how many
    /// later frames overtake the held one.  `0` degrades to immediate
    /// delivery.  Ignored when `reorder_in_at` is `None`.
    pub reorder_gap: u64,
}

impl ReactorFault {
    /// Parse a [`FAULT_ENV`] spec: comma-separated `sever_in:<n>`,
    /// `drop_in:<n>`, `delay_in:<n>:<ms>`, `reorder_in:<n>:<k>`
    /// clauses.  This is the single parser for reactor-side fault
    /// grammars — the trace-anchored plans
    /// ([`crate::trace::anchored_fault`]) build the same struct.
    pub fn parse(spec: &str) -> Result<ReactorFault> {
        let mut fault = ReactorFault::default();
        let mut clauses = 0;
        for clause in spec.split(',') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(n) = clause.strip_prefix("sever_in:") {
                fault.sever_in_at = Some(n.trim().parse()?);
            } else if let Some(n) = clause.strip_prefix("drop_in:") {
                fault.drop_in_at = Some(n.trim().parse()?);
            } else if let Some(rest) = clause.strip_prefix("delay_in:") {
                let (n, ms) = rest
                    .split_once(':')
                    .ok_or_else(|| anyhow::anyhow!("delay_in needs <n>:<ms>"))?;
                fault.delay_in_at = Some(n.trim().parse()?);
                fault.delay_in_ms = ms.trim().parse()?;
            } else if let Some(rest) = clause.strip_prefix("reorder_in:") {
                let (n, k) = rest
                    .split_once(':')
                    .ok_or_else(|| anyhow::anyhow!("reorder_in needs <n>:<k>"))?;
                fault.reorder_in_at = Some(n.trim().parse()?);
                fault.reorder_gap = k.trim().parse()?;
            } else {
                bail!(
                    "bad {FAULT_ENV} clause '{clause}' (expected sever_in:<n>, drop_in:<n>, \
                     delay_in:<n>:<ms>, or reorder_in:<n>:<k>)"
                );
            }
            clauses += 1;
        }
        if clauses == 0 {
            bail!("empty {FAULT_ENV} spec");
        }
        Ok(fault)
    }

    /// The plan a reactor shard should run: an explicit config value
    /// wins; otherwise the [`FAULT_ENV`] env var is consulted (bad
    /// specs are ignored — fault injection must never take down a
    /// production server); `None` means no injected faults.
    pub fn resolve(explicit: Option<ReactorFault>) -> Option<ReactorFault> {
        explicit.or_else(|| {
            std::env::var(FAULT_ENV).ok().and_then(|v| ReactorFault::parse(&v).ok())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::in_proc_pair;

    #[test]
    fn clean_plan_is_transparent() {
        let (a, mut b) = in_proc_pair();
        let mut f = FaultTransport::new(a, FaultPlan::new());
        f.send(b"hello").unwrap();
        assert_eq!(b.recv().unwrap(), b"hello");
        b.send(b"world").unwrap();
        assert_eq!(f.recv().unwrap(), b"world");
        assert_eq!(f.frames(), (1, 1));
        assert_eq!(f.bytes_sent(), 5);
    }

    #[test]
    fn sever_at_nth_send_is_sticky() {
        let (a, mut b) = in_proc_pair();
        let mut f = FaultTransport::new(a, FaultPlan::new().sever_send_at(2));
        f.send(b"0").unwrap();
        f.send(b"1").unwrap();
        assert!(f.send(b"2").is_err(), "frame 2 must sever");
        assert!(f.send(b"3").is_err(), "severed links stay severed");
        assert!(f.recv().is_err(), "both directions die");
        assert_eq!(b.recv().unwrap(), b"0");
        assert_eq!(b.recv().unwrap(), b"1");
    }

    #[test]
    fn sever_at_nth_recv() {
        let (a, mut b) = in_proc_pair();
        let mut f = FaultTransport::new(a, FaultPlan::new().sever_recv_at(1));
        b.send(b"0").unwrap();
        b.send(b"1").unwrap();
        assert_eq!(f.recv().unwrap(), b"0");
        assert!(f.recv().is_err(), "recv frame 1 must sever");
        assert!(f.send(b"x").is_err());
    }

    #[test]
    fn drop_loses_exactly_one_frame() {
        let (a, mut b) = in_proc_pair();
        let mut f = FaultTransport::new(a, FaultPlan::new().drop_send_at(1).drop_recv_at(0));
        f.send(b"s0").unwrap();
        f.send(b"s1").unwrap(); // dropped
        f.send(b"s2").unwrap();
        assert_eq!(b.recv().unwrap(), b"s0");
        assert_eq!(b.recv().unwrap(), b"s2");
        b.send(b"r0").unwrap(); // dropped on receipt
        b.send(b"r1").unwrap();
        assert_eq!(f.recv().unwrap(), b"r1");
        assert_eq!(f.frames(), (3, 2), "dropped frames consume their ordinal");
    }

    #[test]
    fn delay_holds_then_delivers() {
        let (a, mut b) = in_proc_pair();
        let mut f = FaultTransport::new(a, FaultPlan::new().delay_send_at(0, 30));
        let t0 = Instant::now();
        f.send(b"slow").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(29));
        assert_eq!(b.recv().unwrap(), b"slow");
    }

    #[test]
    fn black_hole_swallows_sends_and_times_out_recvs() {
        let (a, mut b) = in_proc_pair();
        let mut f = FaultTransport::new(a, FaultPlan::new().black_hole_send_at(1));
        f.send(b"heard").unwrap();
        f.send(b"void").unwrap(); // enters the hole: reported ok
        f.send(b"void2").unwrap(); // still "ok"
        assert_eq!(b.recv().unwrap(), b"heard");
        b.send(b"reply").unwrap();
        // deadline recv: clean timeout even though a frame is queued
        let got = f.recv_deadline(Instant::now() + Duration::from_millis(20)).unwrap();
        assert!(got.is_none(), "black hole must look like silence");
        // blocking recv: fails after a grace sleep instead of hanging
        assert!(f.recv().is_err());
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        for seed in 0..32u64 {
            let a = FaultPlan::seeded_sever(seed, 100);
            let b = FaultPlan::seeded_sever(seed, 100);
            assert_eq!(a, b, "seed {seed} must rebuild the same plan");
            assert!(!a.is_empty());
        }
        // different seeds land on different points (spot check)
        assert_ne!(FaultPlan::seeded_sever(1, 1000), FaultPlan::seeded_sever(2, 1000));
    }

    #[test]
    fn reactor_fault_spec_parses() {
        assert_eq!(
            ReactorFault::parse("sever_in:48").unwrap(),
            ReactorFault { sever_in_at: Some(48), ..Default::default() }
        );
        assert_eq!(
            ReactorFault::parse(" sever_in: 0 ").unwrap(),
            ReactorFault { sever_in_at: Some(0), ..Default::default() }
        );
        assert_eq!(
            ReactorFault::parse("drop_in:3").unwrap(),
            ReactorFault { drop_in_at: Some(3), ..Default::default() }
        );
        assert_eq!(
            ReactorFault::parse("delay_in:5:250").unwrap(),
            ReactorFault { delay_in_at: Some(5), delay_in_ms: 250, ..Default::default() }
        );
        assert_eq!(
            ReactorFault::parse("reorder_in:4:2").unwrap(),
            ReactorFault { reorder_in_at: Some(4), reorder_gap: 2, ..Default::default() }
        );
        // clauses combine, whitespace tolerated, order irrelevant
        assert_eq!(
            ReactorFault::parse("drop_in:3, sever_in:7").unwrap(),
            ReactorFault { sever_in_at: Some(7), drop_in_at: Some(3), ..Default::default() }
        );
        assert!(ReactorFault::parse("sever_in:").is_err());
        assert!(ReactorFault::parse("delay_in:5").is_err());
        assert!(ReactorFault::parse("reorder_in:4").is_err(), "reorder_in needs the gap");
        assert!(ReactorFault::parse("chaos").is_err());
        assert!(ReactorFault::parse("").is_err());
        // explicit config wins over anything the env might say
        let explicit = ReactorFault { sever_in_at: Some(7), ..Default::default() };
        assert_eq!(ReactorFault::resolve(Some(explicit)), Some(explicit));
    }
}
