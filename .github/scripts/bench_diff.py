#!/usr/bin/env python3
"""Diff two BENCH_hotpath.json artifacts and flag per-bench regressions.

Usage: bench_diff.py PREVIOUS.json CURRENT.json

Compares mean per-iteration seconds bench-by-bench (matched by name).
Prints a trajectory table, emits GitHub warning annotations for benches
that regressed past WARN_RATIO, and exits non-zero past FAIL_RATIO so
the (continue-on-error) CI step shows red without blocking the build.
CI runners are noisy, so the thresholds are deliberately loose and
sub-microsecond benches are compared with an absolute floor.
"""

import json
import sys

WARN_RATIO = 1.30
FAIL_RATIO = 2.00
# ignore regressions where both sides are under this (timer noise)
FLOOR_S = 2e-7


def load(path):
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)}


def fmt(s):
    if s >= 1.0:
        return f"{s:.3f} s"
    if s >= 1e-3:
        return f"{s * 1e3:.3f} ms"
    if s >= 1e-6:
        return f"{s * 1e6:.3f} us"
    return f"{s * 1e9:.1f} ns"


def main():
    prev, cur = load(sys.argv[1]), load(sys.argv[2])
    common = [n for n in cur if n in prev]
    added = [n for n in cur if n not in prev]
    removed = [n for n in prev if n not in cur]

    warnings, failures = [], []
    print(f"{'bench':<48} {'prev':>12} {'cur':>12} {'ratio':>8}")
    for name in common:
        p, c = prev[name]["mean_s"], cur[name]["mean_s"]
        ratio = c / p if p > 0 else float("inf")
        marker = ""
        if c > FLOOR_S and p > 0:
            if ratio >= FAIL_RATIO:
                marker = "  << REGRESSION"
                failures.append((name, p, c, ratio))
            elif ratio >= WARN_RATIO:
                marker = "  <- slower"
                warnings.append((name, p, c, ratio))
        print(f"{name:<48} {fmt(p):>12} {fmt(c):>12} {ratio:>7.2f}x{marker}")

    for name in added:
        print(f"{name:<48} {'-':>12} {fmt(cur[name]['mean_s']):>12}     new")
    for name in removed:
        print(f"{name:<48} {fmt(prev[name]['mean_s']):>12} {'-':>12} removed")

    for name, p, c, ratio in warnings + failures:
        print(
            f"::warning title=bench regression::{name}: "
            f"{fmt(p)} -> {fmt(c)} ({ratio:.2f}x)"
        )

    if failures:
        print(f"\n{len(failures)} bench(es) regressed past {FAIL_RATIO:.1f}x")
        sys.exit(1)
    print(f"\nbench trajectory OK ({len(common)} compared, {len(warnings)} warnings)")


if __name__ == "__main__":
    main()
