#!/usr/bin/env python3
"""Regenerate rust/tests/data/golden_trace.jsonl (TRACE v1 golden recording).

The golden trace is a hand-derived recording of a single-worker mock
cloud run: two devices, a global memory budget tight enough to force
eviction churn, one evicted-request replay, and one mid-run
sever/resume (an honored `resume` reset).  The replayer
(`rust/src/trace/replay.rs`) re-drives it and must reproduce every
token bit-for-bit plus the final counters.

Scenario (workers=1, d_model=128, cloud kv = 5120 B/pos from
`test_manifest`, budget 24000 B, mock oracle seed 1):

  * device 1 (session 0x11) and device 2 (session 0x22) each upload a
    3-position prompt and take the prompt-frontier token (pos 2);
  * serving device 2 pushes residency to 30720 B -> device 1 evicted;
  * device 1's next infer bounces with `evicted_notice`, the edge
    replays its 4-position history (replay counter = 1), and the token
    at pos 3 is served -> device 2 evicted (35840 B over budget);
  * device 2 reconnects with resume=true (honored: suspend clears the
    eviction mark, resumed counter = 1, NOT a replay), re-uploads its
    history, takes pos 3 -> device 1 evicted again (evictions = 3);
  * both requests end; worker 0 emits its stats line.

Every field mirrors what `scheduler.rs` would emit; if the scheduler's
trace schema changes, bump TRACE v and re-derive this file.

Usage: python3 .github/scripts/gen_golden_trace.py [out.jsonl]
"""

import json
import struct
import sys

MASK = (1 << 64) - 1


def splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & MASK
    return (x ^ (x >> 31)) & MASK


SEED = 1
D_MODEL = 128
CONF_BITS = struct.unpack("<I", struct.pack("<f", 0.95))[0]  # 0x3F733333


def token(pos: int) -> int:
    # MockOracle::cloud_token: 97 + splitmix64(seed ^ 0x77 ^ pos) % 26
    return 97 + splitmix64((SEED ^ 0x77 ^ pos) & MASK) % 26


def hidden_hex(positions: int) -> str:
    # 0.5f32 little-endian, d_model floats per position
    return "0000003f" * (positions * D_MODEL)


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else "rust/tests/data/golden_trace.jsonl"
    w = 0  # single worker owns both devices (device % workers == 0)
    events = [
        {"ev": "run_meta", "workers": 1, "d_model": D_MODEL, "max_catchup": 8,
         "budget": 24000},
        {"ev": "reset", "worker": w, "device": 1, "session": "0x11",
         "resume": False, "honored": False},
        {"ev": "reset", "worker": w, "device": 2, "session": "0x22",
         "resume": False, "honored": False},
        # --- device 1 prompt: upload 3 positions, take the frontier token
        {"ev": "upload", "worker": w, "device": 1, "session": "0x11", "req": 1,
         "start": 0, "plen": 3, "data": hidden_hex(3)},
        {"ev": "infer", "worker": w, "device": 1, "session": "0x11", "req": 1,
         "pos": 2, "plen": 3},
        {"ev": "park", "worker": w, "device": 1, "req": 1, "pos": 2},
        {"ev": "pass", "worker": w, "devices": 1, "items": 0},
        {"ev": "token", "worker": w, "device": 1, "req": 1, "pos": 2,
         "token": token(2), "conf_bits": CONF_BITS},
        # --- device 2 prompt: serving it breaks the budget -> evict device 1
        {"ev": "upload", "worker": w, "device": 2, "session": "0x22", "req": 1,
         "start": 0, "plen": 3, "data": hidden_hex(3)},
        {"ev": "infer", "worker": w, "device": 2, "session": "0x22", "req": 1,
         "pos": 2, "plen": 3},
        {"ev": "park", "worker": w, "device": 2, "req": 1, "pos": 2},
        {"ev": "pass", "worker": w, "devices": 1, "items": 0},
        {"ev": "token", "worker": w, "device": 2, "req": 1, "pos": 2,
         "token": token(2), "conf_bits": CONF_BITS},
        {"ev": "evict", "worker": w, "device": 1},
        # --- device 1 bounces, replays its 4-position history (replays = 1)
        {"ev": "infer", "worker": w, "device": 1, "session": "0x11", "req": 1,
         "pos": 3, "plen": 3},
        {"ev": "evicted_notice", "worker": w, "device": 1, "req": 1, "pos": 3},
        {"ev": "upload", "worker": w, "device": 1, "session": "0x11", "req": 1,
         "start": 0, "plen": 3, "data": hidden_hex(4)},
        {"ev": "infer", "worker": w, "device": 1, "session": "0x11", "req": 1,
         "pos": 3, "plen": 3},
        {"ev": "park", "worker": w, "device": 1, "req": 1, "pos": 3},
        {"ev": "pass", "worker": w, "devices": 1, "items": 0},
        {"ev": "token", "worker": w, "device": 1, "req": 1, "pos": 3,
         "token": token(3), "conf_bits": CONF_BITS},
        {"ev": "evict", "worker": w, "device": 2},
        # --- device 2 severed mid-run; reconnect with an honored resume
        #     (resumed = 1; suspend clears the eviction mark, so the
        #     re-upload below is NOT counted as a replay)
        {"ev": "reset", "worker": w, "device": 2, "session": "0x22",
         "resume": True, "honored": True},
        {"ev": "upload", "worker": w, "device": 2, "session": "0x22", "req": 1,
         "start": 0, "plen": 3, "data": hidden_hex(4)},
        {"ev": "infer", "worker": w, "device": 2, "session": "0x22", "req": 1,
         "pos": 3, "plen": 3},
        {"ev": "park", "worker": w, "device": 2, "req": 1, "pos": 3},
        {"ev": "pass", "worker": w, "devices": 1, "items": 0},
        {"ev": "token", "worker": w, "device": 2, "req": 1, "pos": 3,
         "token": token(3), "conf_bits": CONF_BITS},
        {"ev": "evict", "worker": w, "device": 1},
        # --- both requests end; worker 0 reports its final counters
        {"ev": "end", "worker": w, "device": 1, "session": "0x11", "req": 1},
        {"ev": "end", "worker": w, "device": 2, "session": "0x22", "req": 1},
        {"ev": "worker_stats", "worker": w, "served": 4, "uploads": 4,
         "resumed": 1, "stale_resumes": 0, "evictions": 3, "ttl_reaps": 0,
         "replays": 1},
    ]
    with open(out, "w") as f:
        for seq, ev in enumerate(events):
            line = {"v": 1, "seq": seq, "t_us": 1000 + 250 * seq}
            line.update(ev)
            f.write(json.dumps(line) + "\n")
    print(f"wrote {len(events)} events to {out}")


if __name__ == "__main__":
    main()
