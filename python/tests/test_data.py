"""Corpus/grammar tests: the synthetic language must have the properties
the reproduction relies on (byte-level encoding, multi-sentence documents,
rust-side mirroring)."""

import numpy as np
import pytest

from compile import data
from compile.config import BOS_ID, EOS_ID


def rng(seed=0):
    return np.random.default_rng(seed)


def test_sentences_are_ascii_and_terminated():
    r = rng(1)
    for _ in range(50):
        s = data.sample_sentence(r)
        assert s.endswith(".")
        assert s.isascii()
        assert 10 <= len(s) <= 120


def test_encode_decode_roundtrip():
    s = "the machine can compute."
    ids = data.encode(s)
    assert ids.dtype == np.int32
    assert (ids >= 0).all() and (ids < 256).all()
    assert data.decode(ids) == s


def test_corpus_is_documents_with_specials():
    stream = data.make_corpus(rng(2), 60)
    assert stream[0] == BOS_ID
    n_bos = int((stream == BOS_ID).sum())
    n_eos = int((stream == EOS_ID).sum())
    assert n_bos == n_eos and n_bos >= 10
    # multi-sentence documents: average doc must contain >= 2 periods
    docs = n_bos
    periods = int((stream == ord(".")).sum())
    assert periods / docs >= 2.0, "corpus must be multi-sentence documents"


def test_batches_shapes_and_shift():
    stream = data.make_corpus(rng(3), 100)
    it = data.batches(stream, batch_size=4, seq_len=16, rng=rng(4))
    x, y = next(it)
    assert x.shape == (4, 16) and y.shape == (4, 16)
    # y is x shifted by one
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


def test_word_lists_mirror_rust():
    """The rust generator (eval/datasets.rs) must use the same grammar.
    Parse the rust source and compare word lists verbatim."""
    import re
    from pathlib import Path

    src = (Path(__file__).parents[2] / "rust/src/eval/datasets.rs").read_text()

    def rust_list(name):
        m = re.search(rf'pub const {name}: &\[&str\] = &\[(.*?)\];', src, re.S)
        assert m, f"{name} not found in datasets.rs"
        return re.findall(r'"([^"]+)"', m.group(1))

    assert rust_list("NOUNS") == data.NOUNS
    assert rust_list("VERBS") == data.VERBS
    assert rust_list("ADJS") == data.ADJS
    assert rust_list("DETS") == data.DETS


def test_documents_concatenate_sentences():
    doc = data.sample_document(rng(5), 4)
    assert doc.count(".") == 4
