"""AOT exporter tests: weights container format, HLO-text lowering path,
manifest structure.  Uses a tiny config so lowering stays fast."""

import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.config import ModelConfig

TINY = ModelConfig(max_prompt=128, max_seq=128)


def test_weights_container_format(tmp_path):
    path = tmp_path / "w.bin"
    tensors = {
        "a": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b['x']": np.array([1.5], dtype=np.float32),
    }
    aot.write_weights(str(path), tensors)
    raw = path.read_bytes()
    assert raw[:4] == b"CECW"
    version, n = struct.unpack("<II", raw[4:12])
    assert version == 1 and n == 2
    # parse one record by hand
    off = 12
    name_len = struct.unpack("<H", raw[off:off + 2])[0]
    off += 2
    name = raw[off:off + name_len].decode()
    off += name_len
    dtype, ndim = raw[off], raw[off + 1]
    assert dtype == 0
    off += 2
    dims = struct.unpack(f"<{ndim}I", raw[off:off + 4 * ndim])
    off += 4 * ndim
    nbytes = struct.unpack("<Q", raw[off:off + 8])[0]
    assert nbytes == int(np.prod(dims)) * 4
    assert name in tensors


def test_hlo_text_lowering_parses():
    def fn(x, y):
        return {"z": x @ y + 1.0}

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(fn, (spec, spec))
    assert "HloModule" in text
    assert "parameter(0)" in text


def test_keep_unused_params_stay_in_signature():
    # a function ignoring its first arg must still expose it as a parameter
    def fn(unused, x):
        return {"y": x * 2.0}

    spec = jax.ShapeDtypeStruct((2,), jnp.float32)
    text = aot.to_hlo_text(fn, (spec, spec))
    assert "parameter(1)" in text, "unused params must stay (rust passes full sets)"


def test_flat_names_are_deterministic_and_sorted():
    tree = {"b": [jnp.zeros(1), jnp.zeros(2)], "a": {"y": jnp.zeros(3), "x": jnp.zeros(4)}}
    names = aot.flat_names(tree)
    # dict keys flatten sorted; lists in order
    assert names == ["['a']['x']", "['a']['y']", "['b'][0]", "['b'][1]"]


def test_export_artifact_manifest_entry(tmp_path):
    params = M.init_params(TINY, jax.random.PRNGKey(0))
    eparams = M.edge_params(params, TINY)
    tokens = jnp.zeros((TINY.max_prompt,), jnp.int32)
    length = jnp.zeros((), jnp.int32)
    sig = aot.export_artifact(
        str(tmp_path), "edge_prefill",
        lambda p, t, n: M.edge_prefill(p, t, n, TINY),
        eparams, (tokens, length), ["tokens", "length"])
    assert (tmp_path / "edge_prefill.hlo.txt").exists()
    assert [i["name"] for i in sig["inputs"]] == ["tokens", "length"]
    out_names = [o["name"] for o in sig["outputs"]]
    assert "e1_conf" in out_names and "kv1_k" in out_names
    # shapes recorded match the config
    h1 = next(o for o in sig["outputs"] if o["name"] == "h1")
    assert h1["shape"] == [TINY.max_prompt, TINY.d_model]


def test_real_manifest_consistent_with_artifacts():
    art = os.path.join(os.path.dirname(__file__), "../../artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    m = json.load(open(mpath))
    for name, sig in m["artifacts"].items():
        assert os.path.exists(os.path.join(art, sig["file"])), name
        assert m["artifact_params"][name] in m["partitions"]
    # every partition tensor exists in weights.bin (parse names only)
    raw = open(os.path.join(art, "weights.bin"), "rb").read()
    n = struct.unpack("<I", raw[8:12])[0]
    names = set()
    off = 12
    for _ in range(n):
        ln = struct.unpack("<H", raw[off:off + 2])[0]
        off += 2
        names.add(raw[off:off + ln].decode())
        off += ln
        dtype, ndim = raw[off], raw[off + 1]
        off += 2
        dims = struct.unpack(f"<{ndim}I", raw[off:off + 4 * ndim])
        off += 4 * ndim
        nbytes = struct.unpack("<Q", raw[off:off + 8])[0]
        off += 8 + nbytes
    for part in m["partitions"].values():
        for t in part:
            assert t["name"] in names
