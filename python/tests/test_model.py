"""L2 correctness: segment composition == full model; decode == prefill.

These invariants are what make CE-CoLLM's accuracy claims possible at all
(paper Table 2: θ=1.0 → ROUGE-L 1.0 vs the cloud deployment):

  * cloud path (h1 -> layers l_ee1..N -> final head) must produce exactly
    the full-model next-token distribution;
  * the incremental KV-cache decode path must match teacher-forced
    full-sequence forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import BOS_ID, ModelConfig

# small config so eager interpret-mode tests stay fast
CFG = ModelConfig(max_prompt=128, max_seq=128)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def eparams(params):
    return M.edge_params(params, CFG)


@pytest.fixture(scope="module")
def cparams(params):
    return M.cloud_params(params, CFG)


def make_prompt(n):
    rng = np.random.default_rng(0)
    toks = np.full((CFG.max_prompt,), 0, np.int32)
    toks[0] = BOS_ID
    toks[1:n] = rng.integers(97, 122, n - 1)
    return jnp.asarray(toks), n


def test_partition_param_counts(params, eparams, cparams):
    assert len(eparams["layers"]) == CFG.l_ee2
    assert len(cparams["layers"]) == CFG.n_layers - CFG.l_ee1
    # overlap region l_ee1..l_ee2-1 is deployed on BOTH sides (paper Fig 2)
    for j, i in enumerate(range(CFG.l_ee1, CFG.l_ee2)):
        np.testing.assert_array_equal(np.asarray(eparams["layers"][i]["wq"]),
                                      np.asarray(cparams["layers"][j]["wq"]))


def test_prefill_matches_train_forward(params, eparams, cparams):
    """Cloud prefill's final logits == full-model logits at the last pos."""
    tokens, n = make_prompt(17)
    e = jax.jit(lambda p, t, l: M.edge_prefill(p, t, l, CFG))(eparams, tokens, n)
    c = jax.jit(lambda p, h, l: M.cloud_prefill(p, h, l, CFG))(cparams, e["h1"], n)
    e1, e2, fin = M.train_forward(params, tokens[None, :n], CFG)
    np.testing.assert_allclose(c["logits"][0], fin[0, n - 1],
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(e["e1_logits"][0], e1[0, n - 1],
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(e["e2_logits"][0], e2[0, n - 1],
                               rtol=1e-3, atol=1e-3)


def test_decode_matches_teacher_forced(params, eparams, cparams):
    """Prefill(n) + k decode steps == prefill(n+k) at every exit."""
    n, extra = 11, 4
    tokens_full, _ = make_prompt(n + extra)
    tokens_pre = tokens_full.at[n:].set(0)

    jep = jax.jit(lambda p, t, l: M.edge_prefill(p, t, l, CFG))
    jcp = jax.jit(lambda p, h, l: M.cloud_prefill(p, h, l, CFG))
    js1 = jax.jit(lambda p, kk, kv, t, ps: M.edge_seg1_decode(p, kk, kv, t, ps, CFG))
    js2 = jax.jit(lambda p, kk, kv, h, ps: M.edge_seg2_decode(p, kk, kv, h, ps, CFG))
    jcd = jax.jit(lambda p, kk, kv, h, ps: M.cloud_decode(p, kk, kv, h, ps, CFG))

    e = jep(eparams, tokens_pre, n)
    c = jcp(cparams, e["h1"], n)
    kv1_k, kv1_v = e["kv1_k"], e["kv1_v"]
    kv2_k, kv2_v = e["kv2_k"], e["kv2_v"]
    kvc_k, kvc_v = c["kvc_k"], c["kvc_v"]

    for step in range(extra):
        pos = n + step
        tok = tokens_full[pos]
        s1 = js1(eparams, kv1_k, kv1_v, tok, pos)
        kv1_k, kv1_v = s1["kv1_k"], s1["kv1_v"]
        s2 = js2(eparams, kv2_k, kv2_v, s1["h1"], pos)
        kv2_k, kv2_v = s2["kv2_k"], s2["kv2_v"]
        cd = jcd(cparams, kvc_k, kvc_v, s1["h1"], pos)
        kvc_k, kvc_v = cd["kvc_k"], cd["kvc_v"]

    e1, e2, fin = M.train_forward(params, tokens_full[None, :n + extra], CFG)
    last = n + extra - 1
    np.testing.assert_allclose(s1["e1_logits"][0], e1[0, last], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s2["e2_logits"][0], e2[0, last], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(cd["logits"][0], fin[0, last], rtol=1e-3, atol=1e-3)


def test_confidence_consistent_with_logits(eparams):
    tokens, n = make_prompt(9)
    e = jax.jit(lambda p, t, l: M.edge_prefill(p, t, l, CFG))(eparams, tokens, n)
    p1 = jax.nn.softmax(e["e1_logits"][0])
    np.testing.assert_allclose(float(e["e1_conf"]), float(jnp.max(p1)),
                               rtol=1e-4)
    assert int(e["e1_tok"]) == int(jnp.argmax(p1))
    assert 0.0 < float(e["e1_conf"]) <= 1.0 + 1e-6


def test_prompt_padding_is_inert(eparams):
    """Bytes beyond ``length`` must not change any output."""
    tokens, n = make_prompt(13)
    jep = jax.jit(lambda p, t, l: M.edge_prefill(p, t, l, CFG))
    a = jep(eparams, tokens, n)
    b = jep(eparams, tokens.at[n:].set(111), n)
    np.testing.assert_allclose(a["e2_logits"], b["e2_logits"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a["h1"][:n], b["h1"][:n], rtol=1e-5, atol=1e-6)


def test_rope_position_sensitivity():
    """Same token at different positions must produce different queries."""
    x = jnp.ones((4, 1, 32))
    r0 = M.rope(x, jnp.array([0], jnp.int32), 10000.0)
    r5 = M.rope(x, jnp.array([5], jnp.int32), 10000.0)
    assert not np.allclose(r0, r5)
    # position 0 is the identity rotation
    np.testing.assert_allclose(r0, x, rtol=1e-6, atol=1e-6)


def test_prefill_bucket_equivalence(eparams, cparams):
    """The P=64 short-prompt bucket must produce the same exits, hidden
    states and KV caches as the full-size prefill (the rust engine picks
    buckets transparently; see EXPERIMENTS.md §Perf)."""
    import dataclasses
    cfg64 = dataclasses.replace(CFG, max_prompt=64)
    tokens_full, n = make_prompt(21)
    tokens_64 = tokens_full[:64]

    big = jax.jit(lambda p, t, l: M.edge_prefill(p, t, l, CFG))(eparams, tokens_full, n)
    small = jax.jit(lambda p, t, l: M.edge_prefill(p, t, l, cfg64))(eparams, tokens_64, n)

    np.testing.assert_allclose(small["e1_logits"], big["e1_logits"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(small["e2_logits"], big["e2_logits"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(small["h1"][:n], big["h1"][:n], rtol=1e-4, atol=1e-5)
    # KV caches agree on the valid prompt slots (cache shape is max_seq
    # in both buckets)
    np.testing.assert_allclose(np.asarray(small["kv1_k"])[:, :, :n],
                               np.asarray(big["kv1_k"])[:, :, :n], rtol=1e-4, atol=1e-5)

    c_big = jax.jit(lambda p, h, l: M.cloud_prefill(p, h, l, CFG))(cparams, big["h1"], n)
    c_small = jax.jit(lambda p, h, l: M.cloud_prefill(p, h, l, cfg64))(
        cparams, small["h1"], n)
    np.testing.assert_allclose(c_small["logits"], c_big["logits"], rtol=1e-4, atol=1e-5)
