"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/seeds; assert_allclose against kernels/ref.py.
This is the CORE correctness signal for the compute layer — if these pass,
the HLO artifacts executed by rust compute the same numbers as the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, exit_head, ref

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 1e-4, 1e-5


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# --------------------------------------------------------------------------
# exit head
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       d=st.sampled_from([64, 128]),
       v_tiles=st.integers(1, 4),
       scale=st.sampled_from([0.02, 0.1, 1.0]))
def test_exit_head_matches_ref(seed, d, v_tiles, scale):
    V = v_tiles * exit_head.TILE_V
    h = rand(seed, (1, d))
    sc = rand(seed + 1, (d,)) + 1.0
    W = rand(seed + 2, (d, V), scale)
    lg, conf, am = jax.jit(exit_head.exit_head)(h, sc, W)
    lgr, confr, amr = ref.exit_head(h, sc, W)
    np.testing.assert_allclose(lg, lgr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(conf, confr[0], rtol=RTOL, atol=ATOL)
    assert int(am) == int(amr[0])


def test_exit_head_confidence_in_unit_interval():
    for seed in range(10):
        h = rand(seed, (1, 128))
        sc = jnp.ones((128,))
        W = rand(seed + 100, (128, 384), 0.5)
        _, conf, _ = jax.jit(exit_head.exit_head)(h, sc, W)
        assert 0.0 < float(conf) <= 1.0 + 1e-6


def test_exit_head_peaked_distribution_high_conf():
    """A logit vector with one huge entry must give conf ~ 1 at its index."""
    d, V = 128, 384
    h = jnp.ones((1, d))
    sc = jnp.ones((d,))
    W = jnp.zeros((d, V)).at[:, 217].set(1.0)   # logit 217 >> others
    _, conf, am = jax.jit(exit_head.exit_head)(h, sc, W)
    assert int(am) == 217
    assert float(conf) > 0.999


def test_exit_head_rejects_unaligned_vocab():
    with pytest.raises(AssertionError):
        exit_head.exit_head(jnp.ones((1, 128)), jnp.ones((128,)),
                            jnp.ones((128, 100)))


# --------------------------------------------------------------------------
# attention prefill
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heads=st.sampled_from([1, 2, 4]),
       p_tiles=st.integers(1, 2),
       hd=st.sampled_from([16, 32]),
       frac=st.floats(0.1, 1.0))
def test_prefill_matches_ref(seed, heads, p_tiles, hd, frac):
    P = p_tiles * attention.TILE_Q
    length = max(1, int(P * frac))
    q, k, v = (rand(seed + i, (heads, P, hd)) for i in range(3))
    out_k = jax.jit(attention.attention_prefill)(q, k, v, length)
    out_r = ref.attention_prefill(q, k, v, length)
    np.testing.assert_allclose(out_k[:, :length], out_r[:, :length],
                               rtol=RTOL, atol=ATOL)


def test_prefill_padding_rows_are_finite():
    """Padding query rows attend to the valid prefix (harmless — their
    outputs are never read) but must never be NaN/Inf, and must not
    perturb valid rows (checked by test_prefill_matches_ref)."""
    P, length = 256, 57
    q, k, v = (rand(i, (2, P, 32)) for i in range(3))
    out = jax.jit(attention.attention_prefill)(q, k, v, length)
    assert np.isfinite(np.asarray(out)).all()


def test_prefill_is_causal():
    """Changing k/v at position j must not affect outputs at positions < j."""
    P, length, j = 128, 100, 50
    q, k, v = (rand(i + 10, (2, P, 32)) for i in range(3))
    out1 = jax.jit(attention.attention_prefill)(q, k, v, length)
    k2 = k.at[:, j:].add(3.0)
    v2 = v.at[:, j:].add(-2.0)
    out2 = jax.jit(attention.attention_prefill)(q, k2, v2, length)
    np.testing.assert_allclose(out1[:, :j], out2[:, :j], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[:, j:length], out2[:, j:length])


# --------------------------------------------------------------------------
# attention decode
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heads=st.sampled_from([1, 4]),
       s_tiles=st.integers(1, 3),
       hd=st.sampled_from([16, 32]),
       posfrac=st.floats(0.0, 1.0))
def test_decode_matches_ref(seed, heads, s_tiles, hd, posfrac):
    S = s_tiles * attention.TILE_KV
    pos = min(S - 1, int(S * posfrac))
    q = rand(seed, (heads, 1, hd))
    k, v = (rand(seed + i, (heads, S, hd)) for i in (1, 2))
    out_k = jax.jit(attention.attention_decode)(q, k, v, pos)
    out_r = ref.attention_decode(q, k, v, pos)
    np.testing.assert_allclose(out_k, out_r, rtol=RTOL, atol=ATOL)


def test_decode_ignores_future_cache_slots():
    """Garbage beyond ``pos`` in the cache must not change the output."""
    S, pos = 256, 40
    q = rand(0, (4, 1, 32))
    k, v = rand(1, (4, S, 32)), rand(2, (4, S, 32))
    out1 = jax.jit(attention.attention_decode)(q, k, v, pos)
    k2 = k.at[:, pos + 1:].set(99.0)
    v2 = v.at[:, pos + 1:].set(-99.0)
    out2 = jax.jit(attention.attention_decode)(q, k2, v2, pos)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_pos_zero_attends_only_slot_zero():
    q = rand(0, (2, 1, 32))
    k, v = rand(1, (2, 64 * 2, 32)), rand(2, (2, 128, 32))
    out = jax.jit(attention.attention_decode)(q, k[:, :128], v, 0)
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-5)
