"""Synthetic byte-level corpus for build-time training.

The paper trains/uses EE-LLM 7B; its Tables depend only on the *confidence
structure* of the exits: many tokens are easy (predicted confidently at an
early exit — e.g. the tail bytes of a word, closing punctuation) while some
are hard (word choices, content words) and need the full model.

A byte-level LM over a small probabilistic grammar reproduces exactly that
structure: within-word bytes are near-deterministic (high confidence at
exit 1), word boundaries are genuinely uncertain (low confidence, deferred
to deeper layers / the cloud partition).

The same grammar (word lists + templates) is mirrored in
``rust/src/eval/datasets.rs`` so the rust harness generates evaluation
prompts from the model's training distribution.  KEEP THE TWO IN SYNC.
"""

import numpy as np

from .config import BOS_ID, EOS_ID

# --- mirrored in rust/src/eval/datasets.rs ---------------------------------
NOUNS = [
    "machine", "test", "system", "model", "network", "computer", "data",
    "cloud", "edge", "device", "server", "intelligence", "behaviour",
    "ability", "language", "token", "layer", "cache", "latency", "result",
    "question", "answer", "document", "summary", "article", "story",
    "report", "sentence", "paragraph", "response", "request", "signal",
]
VERBS = [
    "exhibit", "generate", "process", "predict", "transmit", "compute",
    "evaluate", "measure", "produce", "describe", "summarize", "explain",
    "analyze", "compare", "reduce", "improve", "accelerate", "support",
]
ADJS = [
    "intelligent", "efficient", "adaptive", "large", "small", "fast",
    "slow", "accurate", "reliable", "local", "remote", "collaborative",
    "early", "final", "hidden", "confident",
]
DETS = ["the", "a", "this", "that", "every", "each"]

# Sentence templates; tokens are word-class markers expanded at sample time.
TEMPLATES = [
    ["D", "N", "is", "a", "N", "of", "a", "N's", "ability", "to", "V", "A", "N"],
    ["D", "A", "N", "can", "V", "D", "N"],
    ["D", "N", "must", "V", "D", "A", "N", "quickly"],
    ["what", "is", "D", "N", "?", "it", "is", "a", "A", "N"],
    ["D", "N", "of", "D", "N", "is", "A"],
    ["to", "V", "is", "to", "V", "D", "A", "N"],
    ["D", "N", "and", "D", "N", "V", "together"],
    ["when", "D", "N", "is", "A", ",", "D", "N", "can", "V"],
]
# ---------------------------------------------------------------------------


def sample_sentence(rng: np.random.Generator) -> str:
    tpl = TEMPLATES[rng.integers(len(TEMPLATES))]
    out = []
    for tok in tpl:
        if tok == "N":
            out.append(NOUNS[rng.integers(len(NOUNS))])
        elif tok == "N's":
            out.append(NOUNS[rng.integers(len(NOUNS))] + "'s")
        elif tok == "V":
            out.append(VERBS[rng.integers(len(VERBS))])
        elif tok == "A":
            out.append(ADJS[rng.integers(len(ADJS))])
        elif tok == "D":
            out.append(DETS[rng.integers(len(DETS))])
        else:
            out.append(tok)
    s = " ".join(out).replace(" ?", "?").replace(" ,", ",").replace(" 's", "'s")
    return s + "."


def sample_document(rng: np.random.Generator, n_sentences: int) -> str:
    return " ".join(sample_sentence(rng) for _ in range(n_sentences))


def encode(text: str) -> np.ndarray:
    """Byte-level encoding; specials are out-of-band (BOS/EOS ids > 255)."""
    return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)


def decode(ids) -> str:
    bs = bytes(int(i) for i in ids if 0 <= int(i) < 256)
    return bs.decode("utf-8", errors="replace")


def make_corpus(rng: np.random.Generator, n_sentences: int) -> np.ndarray:
    """Flat stream of token ids: BOS doc EOS BOS doc EOS ... where each
    document is 2-6 sentences.  Multi-sentence documents teach the model
    to continue past sentence boundaries (generations comparable to the
    paper's ~86-token averages) instead of emitting EOS after one
    sentence."""
    parts = []
    emitted = 0
    while emitted < n_sentences:
        k = int(rng.integers(2, 7))
        doc = sample_document(rng, k)
        emitted += k
        ids = encode(doc)
        parts.append(np.concatenate([[BOS_ID], ids, [EOS_ID]]).astype(np.int32))
    return np.concatenate(parts)


def batches(stream: np.ndarray, batch_size: int, seq_len: int,
            rng: np.random.Generator):
    """Yield (inputs, targets) next-token batches forever."""
    n = len(stream) - seq_len - 1
    while True:
        starts = rng.integers(0, n, size=batch_size)
        x = np.stack([stream[s:s + seq_len] for s in starts])
        y = np.stack([stream[s + 1:s + seq_len + 1] for s in starts])
        yield x, y
