"""Build-time training of the EE-transformer (EE-LLM-style weighted CE).

Trains exit heads 1/2 and the backbone jointly so that exit confidences
have the structure the paper relies on (Table 1): easy byte continuations
confident at exit 1, hard word choices deferred.  Runs once during
``make artifacts``; never on the request path.

Usage: python -m compile.train --out ../artifacts/params.npz
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .config import DEFAULT, DEFAULT_TRAIN, ModelConfig, TrainConfig
from .model import init_params, train_forward


def cross_entropy(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def loss_fn(params, x, y, cfg: ModelConfig, w=(0.3, 0.3, 0.4)):
    e1, e2, fin = train_forward(params, x, cfg)
    return (w[0] * cross_entropy(e1, y)
            + w[1] * cross_entropy(e2, y)
            + w[2] * cross_entropy(fin, y))


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    params = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                          params, mh, vh)
    return params, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig = DEFAULT, tcfg: TrainConfig = DEFAULT_TRAIN,
          verbose: bool = True):
    rng = np.random.default_rng(tcfg.seed)
    stream = data.make_corpus(rng, tcfg.corpus_sentences)
    if verbose:
        print(f"corpus: {len(stream)} tokens")
    batch_iter = data.batches(stream, tcfg.batch_size, tcfg.seq_len, rng)

    params = init_params(cfg, jax.random.PRNGKey(tcfg.seed))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, x, y, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, cfg,
                                                  tcfg.exit_weights)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    losses = []
    for i in range(tcfg.steps):
        x, y = next(batch_iter)
        frac = min(1.0, (i + 1) / max(tcfg.warmup, 1))
        # linear warmup then cosine decay
        lr = tcfg.lr * frac * 0.5 * (1 + np.cos(np.pi * max(0, i - tcfg.warmup)
                                                / max(1, tcfg.steps - tcfg.warmup)))
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y),
                                 jnp.float32(lr))
        losses.append(float(loss))
        if verbose and (i % 100 == 0 or i == tcfg.steps - 1):
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.1f}s)")
    return params, losses


def save_npz(params, path):
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    arrs = {jax.tree_util.keystr(kp): np.asarray(a) for kp, a in flat}
    np.savez(path, **arrs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/params.npz")
    ap.add_argument("--steps", type=int, default=DEFAULT_TRAIN.steps)
    args = ap.parse_args()
    tcfg = TrainConfig(steps=args.steps)
    params, losses = train(DEFAULT, tcfg)
    save_npz(params, args.out)
    print(f"saved params to {args.out}; final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
