"""AOT exporter: lower the five CE-CoLLM segment functions to HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (in ``artifacts/``):
  params.npz            trained parameters (cached; delete to retrain)
  weights.bin           binary tensor container read by rust (model/weights.rs)
  manifest.json         model config + per-artifact input/output signatures
  {edge_prefill, edge_seg1_decode, edge_seg2_decode,
   cloud_prefill, cloud_decode}.hlo.txt

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .config import DEFAULT, DEFAULT_TRAIN, ModelConfig

MAGIC = b"CECW"
VERSION = 1
DTYPE_F32 = 0


# --------------------------------------------------------------------------
# weights.bin container
# --------------------------------------------------------------------------

def write_weights(path: str, tensors: dict):
    """tensors: name -> np.float32 ndarray. Little-endian throughout."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPE_F32, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<Q", arr.nbytes))
            f.write(arr.tobytes())


# --------------------------------------------------------------------------
# lowering helpers
# --------------------------------------------------------------------------

def to_hlo_text(fn, example_args) -> str:
    # keep_unused: each artifact receives the FULL partition parameter list
    # (manifest order) even when a segment touches only a subset — the rust
    # runtime stages one buffer vector per partition and reuses it for
    # every artifact.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec_of(x):
    return jax.ShapeDtypeStruct(np.shape(x), x.dtype)


def flat_names(pytree):
    """Names of leaves in jax flatten order (== jit argument order)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(pytree)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


def sig_entry(name, leaf):
    return {"name": name, "shape": [int(d) for d in np.shape(leaf)],
            "dtype": str(np.asarray(leaf).dtype) if not hasattr(leaf, "dtype")
            else str(leaf.dtype)}


def export_artifact(out_dir, name, fn, params_subset, runtime_args,
                    runtime_names):
    """Lower fn(params, *runtime_args) -> dict and describe its signature.

    The jit argument order is: params leaves (pytree flatten order), then
    runtime args in declared order.  The output dict flattens in sorted-key
    order; both orders are recorded in the manifest for the rust side.
    """
    out = fn(params_subset, *runtime_args)             # eager, for out specs
    out_flat, out_tree = jax.tree_util.tree_flatten(out)
    # keystr of a top-level dict key is "['name']" — strip to bare names
    out_names = [n.replace("['", "").replace("']", "") for n in flat_names(out)]

    param_specs = jax.tree.map(spec_of, params_subset)
    arg_specs = [jax.tree.map(spec_of, a) for a in runtime_args]
    text = to_hlo_text(fn, (param_specs, *arg_specs))
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    sig = {
        "file": fname,
        "inputs": [sig_entry(n, a) for n, a in zip(runtime_names, runtime_args)],
        "outputs": [sig_entry(n, o) for n, o in zip(out_names, out_flat)],
    }
    print(f"  {name}: {len(text)} chars, "
          f"{len(sig['inputs'])} runtime inputs, {len(sig['outputs'])} outputs")
    return sig


# --------------------------------------------------------------------------
# main
# --------------------------------------------------------------------------

def load_or_train_params(out_dir, cfg):
    npz_path = os.path.join(out_dir, "params.npz")
    if os.path.exists(npz_path):
        print(f"loading cached params from {npz_path}")
        loaded = np.load(npz_path)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        flat, tree = jax.tree_util.tree_flatten_with_path(params)
        rebuilt = [jnp.asarray(loaded[jax.tree_util.keystr(kp)])
                   for kp, _ in flat]
        return jax.tree_util.tree_unflatten(tree, rebuilt), None
    from . import train as T
    print("training (one-time, cached to params.npz)...")
    params, losses = T.train(cfg, DEFAULT_TRAIN)
    T.save_npz(params, npz_path)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    cfg = DEFAULT
    os.makedirs(args.out_dir, exist_ok=True)

    params, losses = load_or_train_params(args.out_dir, cfg)
    eparams = M.edge_params(params, cfg)
    cparams = M.cloud_params(params, cfg)

    # ---- weights.bin: every leaf of both partitions, keyed by path ----
    tensors = {}
    for part, p in (("edge", eparams), ("cloud", cparams)):
        flat, _ = jax.tree_util.tree_flatten_with_path(p)
        for kp, leaf in flat:
            tensors[part + jax.tree_util.keystr(kp)] = np.asarray(leaf)
    write_weights(os.path.join(args.out_dir, "weights.bin"), tensors)
    print(f"weights.bin: {len(tensors)} tensors, "
          f"{sum(t.nbytes for t in tensors.values())/1e6:.1f} MB")

    # ---- example runtime inputs ----
    P, S, d = cfg.max_prompt, cfg.max_seq, cfg.d_model
    H, hd = cfg.n_heads, cfg.head_dim
    i32, f32 = jnp.int32, jnp.float32
    tokens = jnp.zeros((P,), i32)
    length = jnp.zeros((), i32)
    pos = jnp.zeros((), i32)
    token = jnp.zeros((), i32)
    h1_full = jnp.zeros((P, d), f32)
    h1_one = jnp.zeros((1, d), f32)
    kv1 = jnp.zeros((cfg.l_ee1, H, S, hd), f32)
    kv2 = jnp.zeros((cfg.l_ee2 - cfg.l_ee1, H, S, hd), f32)
    kvc = jnp.zeros((cfg.n_layers - cfg.l_ee1, H, S, hd), f32)

    print("lowering artifacts:")
    artifacts = {}
    artifacts["edge_prefill"] = export_artifact(
        args.out_dir, "edge_prefill",
        lambda p, t, n: M.edge_prefill(p, t, n, cfg),
        eparams, (tokens, length), ["tokens", "length"])
    # short-prompt bucket: same function lowered at P=64 (perf: avoids
    # paying the full 256-position pad for ~30-byte Alpaca-style prompts;
    # EXPERIMENTS.md §Perf).  KV cache shapes are untouched (max_seq).
    import dataclasses
    cfg64 = dataclasses.replace(cfg, max_prompt=64)
    tokens64 = jnp.zeros((64,), i32)
    h1_64 = jnp.zeros((64, d), f32)
    artifacts["edge_prefill_64"] = export_artifact(
        args.out_dir, "edge_prefill_64",
        lambda p, t, n: M.edge_prefill(p, t, n, cfg64),
        eparams, (tokens64, length), ["tokens", "length"])
    artifacts["edge_seg1_decode"] = export_artifact(
        args.out_dir, "edge_seg1_decode",
        lambda p, kk, kv, t, ps: M.edge_seg1_decode(p, kk, kv, t, ps, cfg),
        eparams, (kv1, kv1, token, pos), ["kv1_k", "kv1_v", "token", "pos"])
    artifacts["edge_seg2_decode"] = export_artifact(
        args.out_dir, "edge_seg2_decode",
        lambda p, kk, kv, h, ps: M.edge_seg2_decode(p, kk, kv, h, ps, cfg),
        eparams, (kv2, kv2, h1_one, pos), ["kv2_k", "kv2_v", "h1", "pos"])
    artifacts["cloud_prefill"] = export_artifact(
        args.out_dir, "cloud_prefill",
        lambda p, h, n: M.cloud_prefill(p, h, n, cfg),
        cparams, (h1_full, length), ["h1", "length"])
    artifacts["cloud_prefill_64"] = export_artifact(
        args.out_dir, "cloud_prefill_64",
        lambda p, h, n: M.cloud_prefill(p, h, n, cfg64),
        cparams, (h1_64, length), ["h1", "length"])
    artifacts["cloud_decode"] = export_artifact(
        args.out_dir, "cloud_decode",
        lambda p, kk, kv, h, ps: M.cloud_decode(p, kk, kv, h, ps, cfg),
        cparams, (kvc, kvc, h1_one, pos), ["kvc_k", "kvc_v", "h1", "pos"])

    manifest = {
        "model": cfg.to_dict(),
        "partitions": {
            "edge": [sig_entry("edge" + n, l) for n, l in
                     zip(flat_names(eparams),
                         jax.tree_util.tree_flatten(eparams)[0])],
            "cloud": [sig_entry("cloud" + n, l) for n, l in
                      zip(flat_names(cparams),
                          jax.tree_util.tree_flatten(cparams)[0])],
        },
        "artifact_params": {
            "edge_prefill": "edge", "edge_prefill_64": "edge",
            "edge_seg1_decode": "edge",
            "edge_seg2_decode": "edge", "cloud_prefill": "cloud",
            "cloud_prefill_64": "cloud", "cloud_decode": "cloud",
        },
        "artifacts": artifacts,
        "final_train_loss": losses[-1] if losses else None,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("manifest.json written")


if __name__ == "__main__":
    main()
