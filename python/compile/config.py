"""Model and AOT configuration for the CE-CoLLM reproduction.

A single source of truth shared by the kernels (L1), the jax model (L2),
the trainer, and the AOT exporter.  The rust coordinator (L3) reads the
same values from ``artifacts/manifest.json``.

Layer indexing follows the paper: layers are 1-indexed in prose
(``l_ee1``, ``l_ee2``), 0-indexed in code.  The edge partition holds
layers ``0 .. l_ee2-1`` with exit heads after layer ``l_ee1-1`` (exit 1)
and layer ``l_ee2-1`` (exit 2).  The cloud partition holds layers
``l_ee1 .. n_layers-1`` plus the final LM head, i.e. it resumes from the
hidden state the edge uploads at exit 1 (paper Fig. 2/3: the region
``l_ee1 .. l_ee2-1`` is computed on *both* sides — the overlap).
"""

from dataclasses import dataclass, asdict, field


# Special tokens appended after the 256 byte values.
BOS_ID = 256
EOS_ID = 257
PAD_ID = 258


@dataclass(frozen=True)
class ModelConfig:
    """EE-LLM-style byte-level transformer, scaled for a CPU testbed."""

    vocab_size: int = 384          # 256 bytes + specials, padded to 3*128 lanes
    d_model: int = 128
    n_layers: int = 8
    n_heads: int = 4
    ffn_hidden: int = 512
    l_ee1: int = 3                 # exit 1 after layer 3 (1-indexed)
    l_ee2: int = 5                 # exit 2 after layer 5 (1-indexed)
    max_prompt: int = 256          # static prefill length (padded)
    max_seq: int = 384             # KV cache capacity
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    # --- partition boundaries (0-indexed, half-open ranges) ---
    @property
    def edge_seg1_layers(self) -> range:
        """Layers run by the edge before exit 1."""
        return range(0, self.l_ee1)

    @property
    def edge_seg2_layers(self) -> range:
        """Layers run by the edge between exit 1 and exit 2."""
        return range(self.l_ee1, self.l_ee2)

    @property
    def cloud_layers(self) -> range:
        """Layers run by the cloud, resuming from the exit-1 hidden state."""
        return range(self.l_ee1, self.n_layers)

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["bos_id"] = BOS_ID
        d["eos_id"] = EOS_ID
        d["pad_id"] = PAD_ID
        return d


@dataclass(frozen=True)
class TrainConfig:
    """Build-time training of the exit heads (EE-LLM-style weighted CE)."""

    seed: int = 0
    batch_size: int = 16
    seq_len: int = 96
    steps: int = 350
    lr: float = 3e-3
    warmup: int = 50
    # loss weights for (exit1, exit2, final) — EE-LLM style
    exit_weights: tuple = (0.3, 0.3, 0.4)
    corpus_sentences: int = 4000


DEFAULT = ModelConfig()
DEFAULT_TRAIN = TrainConfig()
