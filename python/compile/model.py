"""L2: the EE-LLM-style byte-level transformer, segmented for CE-CoLLM.

Two families of forward functions:

* ``train_forward`` — full model over a batch of sequences, logits at every
  exit (exit1, exit2, final).  Uses the pure-jnp reference ops (identical
  math to the kernels, faster to compile) — build-time only.

* The five AOT segment functions (``edge_prefill``, ``edge_seg1_decode``,
  ``edge_seg2_decode``, ``cloud_prefill``, ``cloud_decode``) — call the
  Pallas kernels (L1) and are lowered to the HLO artifacts the rust
  runtime executes.  KV caches are explicit inputs/outputs.

Partitioning (paper Fig. 2/3), 0-indexed with cfg = ModelConfig():
  edge seg1 = layers [0, l_ee1)   + exit head 1   (hidden h1 uploaded)
  edge seg2 = layers [l_ee1, l_ee2) + exit head 2
  cloud     = layers [l_ee1, n_layers) + final head   (overlap with seg2)
"""

import dataclasses

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ref
from .kernels.attention import attention_decode, attention_prefill
from .kernels.exit_head import exit_head as pallas_exit_head


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    """Initialize the full-model parameter pytree (plain nested dicts)."""
    d, f, V = cfg.d_model, cfg.ffn_hidden, cfg.vocab_size
    k_emb, k_layers, k_heads = jax.random.split(key, 3)

    def dense(k, shape):
        fan_in = shape[0]
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)

    layers = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(jax.random.fold_in(k_layers, i), 7)
        layers.append({
            "attn_norm": jnp.ones((d,), jnp.float32),
            "wq": dense(ks[0], (d, d)),
            "wk": dense(ks[1], (d, d)),
            "wv": dense(ks[2], (d, d)),
            "wo": dense(ks[3], (d, d)),
            "mlp_norm": jnp.ones((d,), jnp.float32),
            "w_gate": dense(ks[4], (d, f)),
            "w_up": dense(ks[5], (d, f)),
            "w_down": dense(ks[6], (f, d)),
        })

    def head(k):
        return {"norm": jnp.ones((d,), jnp.float32), "unembed": dense(k, (d, V))}

    kh = jax.random.split(k_heads, 3)
    return {
        "tok_emb": jax.random.normal(k_emb, (V, d), jnp.float32) * 0.02,
        "layers": layers,
        "exit1": head(kh[0]),
        "exit2": head(kh[1]),
        "final": head(kh[2]),
    }


def edge_params(params: dict, cfg: ModelConfig) -> dict:
    """The subset of parameters deployed to the edge device."""
    return {
        "tok_emb": params["tok_emb"],
        "layers": [params["layers"][i] for i in range(cfg.l_ee2)],
        "exit1": params["exit1"],
        "exit2": params["exit2"],
    }


def cloud_params(params: dict, cfg: ModelConfig) -> dict:
    """The subset of parameters deployed to the cloud server."""
    return {
        "layers": [params["layers"][i] for i in cfg.cloud_layers],
        "final": params["final"],
    }


# --------------------------------------------------------------------------
# Shared blocks
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Rotary embedding. x: [H, T, hd], positions: [T] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]   # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(lp, x, positions, cfg):
    """Project + rope. x: [T, d] -> q, k, v: [H, T, hd]."""
    T = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    xn = ref.rmsnorm(x, lp["attn_norm"], cfg.norm_eps)
    q = (xn @ lp["wq"]).reshape(T, H, hd).transpose(1, 0, 2)
    k = (xn @ lp["wk"]).reshape(T, H, hd).transpose(1, 0, 2)
    v = (xn @ lp["wv"]).reshape(T, H, hd).transpose(1, 0, 2)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp(lp, x, cfg):
    xn = ref.rmsnorm(x, lp["mlp_norm"], cfg.norm_eps)
    return (jax.nn.silu(xn @ lp["w_gate"]) * (xn @ lp["w_up"])) @ lp["w_down"]


def layer_prefill(lp, x, length, cfg, *, use_kernels=True):
    """One transformer layer over a [P, d] (padded) prompt.

    Returns (x_out [P, d], k [H, P, hd], v [H, P, hd]).
    """
    P = x.shape[0]
    q, k, v = _qkv(lp, x, jnp.arange(P, dtype=jnp.int32), cfg)
    attn_fn = attention_prefill if use_kernels else ref.attention_prefill
    o = attn_fn(q, k, v, length)                       # [H, P, hd]
    o = o.transpose(1, 0, 2).reshape(P, cfg.d_model) @ lp["wo"]
    x = x + o
    x = x + _mlp(lp, x, cfg)
    return x, k, v


def layer_decode(lp, x, k_cache, v_cache, pos, cfg, *, use_kernels=True):
    """One transformer layer for a single token at ``pos``.

    x: [1, d].  k_cache/v_cache: [H, S, hd] (this layer's slice).
    Returns (x_out [1, d], k_cache', v_cache').
    """
    q, k, v = _qkv(lp, x, jnp.full((1,), pos, jnp.int32), cfg)
    # write this step's k/v into slot ``pos``
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0))
    attn_fn = attention_decode if use_kernels else ref.attention_decode
    o = attn_fn(q, k_cache, v_cache, pos)              # [H, 1, hd]
    o = o.transpose(1, 0, 2).reshape(1, cfg.d_model) @ lp["wo"]
    x = x + o
    x = x + _mlp(lp, x, cfg)
    return x, k_cache, v_cache


def head_last(hp, h_last, cfg, *, use_kernels=True):
    """Exit head on a single [1, d] hidden. Returns (logits[1,V], conf, argmax)."""
    if use_kernels:
        return pallas_exit_head(h_last, hp["norm"], hp["unembed"], cfg.norm_eps)
    lg, conf, am = ref.exit_head(h_last, hp["norm"], hp["unembed"], cfg.norm_eps)
    return lg, conf[0], am[0]


# --------------------------------------------------------------------------
# Training forward (full model, all exits, batched)
# --------------------------------------------------------------------------

def train_forward(params, tokens, cfg: ModelConfig):
    """tokens: [B, T] int32 -> (exit1, exit2, final) logits, each [B, T, V]."""

    def one(seq):
        T = seq.shape[0]
        x = params["tok_emb"][seq]
        positions = jnp.arange(T, dtype=jnp.int32)
        exits = {}
        for i, lp in enumerate(params["layers"]):
            q, k, v = _qkv(lp, x, positions, cfg)
            o = ref.attention_prefill(q, k, v, T)
            o = o.transpose(1, 0, 2).reshape(T, cfg.d_model) @ lp["wo"]
            x = x + o
            x = x + _mlp(lp, x, cfg)
            if i == cfg.l_ee1 - 1:
                exits["exit1"] = x
            if i == cfg.l_ee2 - 1:
                exits["exit2"] = x

        def head_all(hp, h):
            return ref.rmsnorm(h, hp["norm"], cfg.norm_eps) @ hp["unembed"]

        return (head_all(params["exit1"], exits["exit1"]),
                head_all(params["exit2"], exits["exit2"]),
                head_all(params["final"], x))

    return jax.vmap(one)(tokens)


# --------------------------------------------------------------------------
# AOT segment functions (pallas kernels; single sequence, static shapes)
# --------------------------------------------------------------------------

def _empty_cache(n_layers, cfg):
    return jnp.zeros((n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim),
                     jnp.float32)


def edge_prefill(eparams, tokens, length, cfg: ModelConfig):
    """Edge prefill over a padded prompt.

    Args:
      eparams: edge parameter subset (see ``edge_params``).
      tokens: [max_prompt] int32, padded with PAD_ID beyond ``length``.
      length: scalar int32.
    Returns dict:
      kv1_k/kv1_v: [l_ee1, H, S, hd] seg1 caches (prompt slots filled),
      kv2_k/kv2_v: [l_ee2-l_ee1, ...] seg2 caches,
      h1: [max_prompt, d] hidden states at exit 1 (the upload payload),
      e1_logits/e1_conf/e1_tok, e2_logits/e2_conf/e2_tok: exit heads at the
      last valid prompt position (the first generated-token decision).
    """
    P = cfg.max_prompt
    x = eparams["tok_emb"][tokens]                       # [P, d]
    kv1_k = _empty_cache(cfg.l_ee1, cfg)
    kv1_v = _empty_cache(cfg.l_ee1, cfg)
    kv2_k = _empty_cache(cfg.l_ee2 - cfg.l_ee1, cfg)
    kv2_v = _empty_cache(cfg.l_ee2 - cfg.l_ee1, cfg)

    for i in range(cfg.l_ee1):
        x, k, v = layer_prefill(eparams["layers"][i], x, length, cfg)
        kv1_k = kv1_k.at[i, :, :P].set(k)
        kv1_v = kv1_v.at[i, :, :P].set(v)
    h1 = x                                                # exit-1 hidden, [P, d]

    last = jnp.clip(length - 1, 0, P - 1)
    h_last1 = jax.lax.dynamic_slice(h1, (last, 0), (1, cfg.d_model))
    e1_logits, e1_conf, e1_tok = head_last(eparams["exit1"], h_last1, cfg)

    for j, i in enumerate(range(cfg.l_ee1, cfg.l_ee2)):
        x, k, v = layer_prefill(eparams["layers"][i], x, length, cfg)
        kv2_k = kv2_k.at[j, :, :P].set(k)
        kv2_v = kv2_v.at[j, :, :P].set(v)

    h_last2 = jax.lax.dynamic_slice(x, (last, 0), (1, cfg.d_model))
    e2_logits, e2_conf, e2_tok = head_last(eparams["exit2"], h_last2, cfg)

    return {
        "kv1_k": kv1_k, "kv1_v": kv1_v, "kv2_k": kv2_k, "kv2_v": kv2_v,
        "h1": h1,
        "e1_logits": e1_logits, "e1_conf": e1_conf, "e1_tok": e1_tok,
        "e2_logits": e2_logits, "e2_conf": e2_conf, "e2_tok": e2_tok,
    }


def edge_seg1_decode(eparams, kv1_k, kv1_v, token, pos, cfg: ModelConfig):
    """Edge layers [0, l_ee1) for one token + exit head 1.

    Returns dict: kv1_k/kv1_v updated, h1 [1, d] (upload payload),
    e1_logits [1, V], e1_conf, e1_tok.
    """
    x = eparams["tok_emb"][token][None, :]
    for i in range(cfg.l_ee1):
        x, kc, vc = layer_decode(eparams["layers"][i], x,
                                 kv1_k[i], kv1_v[i], pos, cfg)
        kv1_k = kv1_k.at[i].set(kc)
        kv1_v = kv1_v.at[i].set(vc)
    e1_logits, e1_conf, e1_tok = head_last(eparams["exit1"], x, cfg)
    return {"kv1_k": kv1_k, "kv1_v": kv1_v, "h1": x,
            "e1_logits": e1_logits, "e1_conf": e1_conf, "e1_tok": e1_tok}


def edge_seg2_decode(eparams, kv2_k, kv2_v, h1, pos, cfg: ModelConfig):
    """Edge layers [l_ee1, l_ee2) from the exit-1 hidden + exit head 2."""
    x = h1
    for j, i in enumerate(range(cfg.l_ee1, cfg.l_ee2)):
        x, kc, vc = layer_decode(eparams["layers"][i], x,
                                 kv2_k[j], kv2_v[j], pos, cfg)
        kv2_k = kv2_k.at[j].set(kc)
        kv2_v = kv2_v.at[j].set(vc)
    e2_logits, e2_conf, e2_tok = head_last(eparams["exit2"], x, cfg)
    return {"kv2_k": kv2_k, "kv2_v": kv2_v,
            "e2_logits": e2_logits, "e2_conf": e2_conf, "e2_tok": e2_tok}


def cloud_prefill(cparams, h1, length, cfg: ModelConfig):
    """Cloud layers [l_ee1, n_layers) over the uploaded prompt hiddens.

    Args:
      h1: [max_prompt, d] exit-1 hidden states (fp32; the wire carries fp16,
        rust up-converts before execution — paper §4.3).
    Returns dict: kvc_k/kvc_v [n_cloud, H, S, hd], plus final-head outputs at
    the last valid position (cloud's first-token decision).
    """
    P = cfg.max_prompt
    n_cloud = cfg.n_layers - cfg.l_ee1
    kvc_k = _empty_cache(n_cloud, cfg)
    kvc_v = _empty_cache(n_cloud, cfg)
    x = h1
    for j, i in enumerate(cfg.cloud_layers):
        x, k, v = layer_prefill(cparams["layers"][j], x, length, cfg)
        kvc_k = kvc_k.at[j, :, :P].set(k)
        kvc_v = kvc_v.at[j, :, :P].set(v)
    last = jnp.clip(length - 1, 0, P - 1)
    h_last = jax.lax.dynamic_slice(x, (last, 0), (1, cfg.d_model))
    logits, conf, tok = head_last(cparams["final"], h_last, cfg)
    return {"kvc_k": kvc_k, "kvc_v": kvc_v,
            "logits": logits, "conf": conf, "tok": tok}


def cloud_decode(cparams, kvc_k, kvc_v, h1, pos, cfg: ModelConfig):
    """Cloud layers [l_ee1, n_layers) for one token from the uploaded h1."""
    x = h1
    for j, _ in enumerate(cfg.cloud_layers):
        x, kc, vc = layer_decode(cparams["layers"][j], x,
                                 kvc_k[j], kvc_v[j], pos, cfg)
        kvc_k = kvc_k.at[j].set(kc)
        kvc_v = kvc_v.at[j].set(vc)
    logits, conf, tok = head_last(cparams["final"], x, cfg)
    return {"kvc_k": kvc_k, "kvc_v": kvc_v,
            "logits": logits, "conf": conf, "tok": tok}
