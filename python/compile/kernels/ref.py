"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(``python/tests/test_kernel.py``) sweeps shapes/seeds with hypothesis and
asserts allclose.  The trainer also uses these (faster to compile than the
interpret-mode kernels; identical math).
"""

import jax
import jax.numpy as jnp


def rmsnorm(x, scale, eps: float = 1e-5):
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def exit_head(h, norm_scale, unembed, eps: float = 1e-5):
    """Fused exit head: rmsnorm -> unembed -> softmax stats.

    Args:
      h: [T, d] hidden states.
      norm_scale: [d].
      unembed: [d, V].
    Returns:
      logits [T, V], conf [T] (max softmax prob), argmax [T] (int32).
    """
    logits = rmsnorm(h, norm_scale, eps) @ unembed
    probs = jax.nn.softmax(logits, axis=-1)
    return logits, jnp.max(probs, axis=-1), jnp.argmax(logits, axis=-1).astype(jnp.int32)


def attention_prefill(q, k, v, length, causal: bool = True):
    """Multi-head causal attention over a (padded) prompt.

    Args:
      q, k, v: [H, P, hd].
      length: scalar int — valid prompt length (positions >= length padded).
    Returns:
      out: [H, P, hd].
    """
    hd = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(hd).astype(q.dtype)
    P = q.shape[1]
    qi = jnp.arange(P)[:, None]
    kj = jnp.arange(P)[None, :]
    mask = kj <= qi if causal else jnp.ones((P, P), bool)
    mask = mask & (kj < length)
    scores = jnp.where(mask[None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (padding queries) produce nan via -inf softmax; zero them
    w = jnp.where(jnp.isnan(w), 0.0, w)
    return jnp.einsum("hqk,hkd->hqd", w, v)


def attention_decode(q, k_cache, v_cache, pos):
    """Single-query attention against a KV cache.

    Args:
      q: [H, 1, hd] query for position ``pos``.
      k_cache, v_cache: [H, S, hd]; positions 0..pos are valid.
      pos: scalar int32 — current position (attends to 0..pos inclusive;
        slot ``pos`` must already contain this step's k/v).
    Returns:
      out: [H, 1, hd].
    """
    hd = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k_cache) / jnp.sqrt(hd).astype(q.dtype)
    S = k_cache.shape[1]
    mask = jnp.arange(S)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", w, v_cache)
