"""Pallas flash-attention kernels (prefill + decode) for the EE-transformer.

TPU mapping (DESIGN.md §Hardware-Adaptation): instead of a threadblock-
per-query-tile GPU schedule, the HBM<->VMEM schedule is expressed with a
(heads, q-tiles) grid and BlockSpecs; each grid step streams KV tiles of
TILE_KV rows through VMEM with flash-style online-softmax accumulators
carried in registers (fori_loop values).  No [P, P] score matrix is ever
materialized.

Both kernels use interpret=True: they lower to plain HLO so the rust
PJRT-CPU runtime can execute them; on a real TPU the same BlockSpecs give
MXU-shaped (128-lane) tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_Q = 128
TILE_KV = 128
NEG_INF = -1e30


def _prefill_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, tile_kv, tile_q=TILE_Q):
    """One (head, q-tile) grid step of causal flash attention."""
    qi = pl.program_id(1)
    q = q_ref[0]                          # [TILE_Q, hd]
    hd = q.shape[-1]
    P = k_ref.shape[1]
    length = len_ref[0]
    scale = 1.0 / jnp.sqrt(hd).astype(q.dtype)

    n_kv = P // tile_kv

    def body(kj, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], kj * tile_kv, tile_kv, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], kj * tile_kv, tile_kv, 0)
        s = (q @ k.T) * scale             # [tile_q, tile_kv]
        q_pos = qi * tile_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = kj * tile_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (k_pos <= q_pos) & (k_pos < length)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    acc0 = jnp.zeros_like(q)
    m0 = jnp.full((q.shape[0],), NEG_INF, q.dtype)
    l0 = jnp.zeros((q.shape[0],), q.dtype)
    # causal: kv tiles strictly above this q tile contribute nothing
    n_live = jnp.minimum(qi + 1, n_kv)
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    # padding query rows have l == 0 (all keys masked); emit zeros not nan
    safe_l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = acc / safe_l[:, None]


def attention_prefill(q, k, v, length):
    """Causal flash attention over a padded prompt.

    Args:
      q, k, v: [H, P, hd]; P a multiple of the tile (tiles shrink to P for
        short-prompt buckets, e.g. the P=64 prefill artifact).
      length: scalar int32 — valid prompt length.
    Returns:
      out [H, P, hd]; rows >= length are garbage-but-finite (never read).
    """
    H, P, hd = q.shape
    tile_q = min(TILE_Q, P)
    tile_kv = min(TILE_KV, P)
    assert P % tile_q == 0, f"prompt pad {P} must be a multiple of {tile_q}"
    length = jnp.asarray(length, jnp.int32).reshape((1,))

    return pl.pallas_call(
        functools.partial(_prefill_kernel, tile_kv=tile_kv, tile_q=tile_q),
        grid=(H, P // tile_q),
        in_specs=[
            pl.BlockSpec((1,), lambda h, i: (0,)),
            pl.BlockSpec((1, tile_q, hd), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, P, hd), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, P, hd), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, hd), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, P, hd), q.dtype),
        interpret=True,
    )(length, q, k, v)


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, tile_kv):
    """One head of single-query flash decode against the KV cache."""
    q = q_ref[0]                          # [1, hd]
    hd = q.shape[-1]
    S = k_ref.shape[1]
    pos = pos_ref[0]
    scale = 1.0 / jnp.sqrt(hd).astype(q.dtype)
    n_kv = S // tile_kv

    def body(kj, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], kj * tile_kv, tile_kv, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], kj * tile_kv, tile_kv, 0)
        s = (q @ k.T) * scale             # [1, TILE_KV]
        k_pos = kj * tile_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    # only tiles containing positions <= pos are live
    n_live = pos // tile_kv + 1
    n_live = jnp.minimum(n_live, n_kv)
    acc0 = jnp.zeros_like(q)
    m0 = jnp.full((1,), NEG_INF, q.dtype)
    l0 = jnp.zeros((1,), q.dtype)
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    o_ref[0] = acc / l[:, None]


def attention_decode(q, k_cache, v_cache, pos):
    """Single-query flash decode.

    Args:
      q: [H, 1, hd] query at position ``pos``.
      k_cache, v_cache: [H, S, hd]; slot ``pos`` already holds this step's k/v.
      pos: scalar int32.
    Returns:
      out [H, 1, hd].
    """
    H, S, hd = k_cache.shape
    assert S % TILE_KV == 0, f"cache len {S} must be a multiple of {TILE_KV}"
    pos = jnp.asarray(pos, jnp.int32).reshape((1,))

    return pl.pallas_call(
        functools.partial(_decode_kernel, tile_kv=TILE_KV),
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((1, 1, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, 1, hd), q.dtype),
        interpret=True,
    )(pos, q, k_cache, v_cache)
