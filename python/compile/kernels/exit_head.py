"""Pallas fused exit-head kernel: rmsnorm -> unembed matmul -> online softmax.

CE-CoLLM evaluates an exit head at *every* exit point for *every* token
(paper §4.4 step 2), so this is one of the two compute hot-spots.  The naive
formulation materializes the full [T, V] logits in HBM three times (norm
output, logits, softmax); this kernel keeps everything VMEM-resident and
produces the confidence (max softmax probability) in the same pass using
flash-style online (m, l) accumulators over vocab tiles.

TPU mapping (DESIGN.md §Hardware-Adaptation): the unembed matmul
[1,d]x[d,V] is tiled along V in lanes-of-128 blocks feeding the MXU; the
(m, l, argmax) accumulators live in the stats *output* block, exploiting
Pallas's sequential grid guarantee (same trick as scratch, but portable to
interpret mode).  VMEM footprint per grid step: d*TILE_V*4 = 64 KiB for the
weight tile + negligible vectors.

Confidence identity used: with l = sum_j exp(logit_j - m) and
m = max_j logit_j, the max softmax probability is exactly 1/l.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_V = 128


def _kernel(h_ref, scale_ref, w_ref, logits_ref, stats_ref, *, eps):
    j = pl.program_id(0)

    # rmsnorm of the [1, d] hidden (d fully resident; recomputed per tile —
    # 3 flops/elem, cheaper than a cross-step staging buffer)
    h = h_ref[...]
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    hn = h * jax.lax.rsqrt(var + eps) * scale_ref[...]

    lg = hn @ w_ref[...]              # [1, TILE_V] on the MXU
    logits_ref[...] = lg

    @pl.when(j == 0)
    def _init():
        stats_ref[0, 0] = -jnp.inf    # running max m
        stats_ref[0, 1] = 0.0         # running sumexp l (relative to m)
        stats_ref[0, 2] = 0.0         # running argmax (stored as f32)

    m_prev = stats_ref[0, 0]
    l_prev = stats_ref[0, 1]
    a_prev = stats_ref[0, 2]

    tile_max = jnp.max(lg)
    tile_arg = (jnp.argmax(lg[0]) + j * TILE_V).astype(jnp.float32)
    m_new = jnp.maximum(m_prev, tile_max)
    l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(jnp.exp(lg - m_new))

    stats_ref[0, 0] = m_new
    stats_ref[0, 1] = l_new
    stats_ref[0, 2] = jnp.where(tile_max > m_prev, tile_arg, a_prev)


def exit_head(h, norm_scale, unembed, eps: float = 1e-5):
    """Fused exit head for a single position.

    Args:
      h: [1, d] hidden state.
      norm_scale: [d] rmsnorm scale.
      unembed: [d, V] unembedding matrix; V % 128 == 0.
    Returns:
      logits [1, V], conf [] (max softmax prob, f32), argmax [] (int32).
    """
    d = h.shape[-1]
    V = unembed.shape[-1]
    assert V % TILE_V == 0, f"vocab {V} must be a multiple of {TILE_V}"

    logits, stats = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(V // TILE_V,),
        in_specs=[
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((d, TILE_V), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_V), lambda j: (0, j)),
            pl.BlockSpec((1, 4), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, V), jnp.float32),
            jax.ShapeDtypeStruct((1, 4), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT target; lowers to plain HLO
    )(h, norm_scale[None, :], unembed)

    conf = 1.0 / stats[0, 1]
    argmax = stats[0, 2].astype(jnp.int32)
    return logits, conf, argmax
