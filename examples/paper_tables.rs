//! Regenerate every table and figure of the paper in one run and write
//! the output to a report file (default `paper_report.md`).
//!
//!     cargo run --release --example paper_tables -- [--prompts 25]
//!         [--repeats 5] [--link paper] [--out paper_report.md]

use std::fmt::Write as _;

use anyhow::Result;

use ce_collm::harness::runner::{record_main_experiments, ExperimentConfig};
use ce_collm::harness::tables;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::runtime::stack::LocalStack;
use ce_collm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let stack = LocalStack::load(args.get_or("artifacts", "artifacts"))?;
    let cfg = ExperimentConfig {
        n_prompts: args.get_parse("prompts", 25),
        repeats: args.get_parse("repeats", 5),
        max_new_tokens: args.get_parse("max-new", 96),
        seed: args.get_parse("seed", 42),
    };
    let link = LinkProfile::by_name(&args.get_or("link", "paper")).expect("link profile");
    let out_path = args.get_or("out", "paper_report.md");

    let mut edge = stack.edge_session();
    let mut cloud = stack.cloud_session();
    let dims = &stack.manifest.model;
    let mut report = String::new();

    writeln!(report, "# CE-CoLLM reproduction report\n")?;
    writeln!(
        report,
        "config: {} prompts/dataset, {} repeats, max_new={}, link={}, seed={}\n",
        cfg.n_prompts, cfg.repeats, cfg.max_new_tokens, link.name, cfg.seed
    )?;

    eprintln!("Table 1 (exit confidences)...");
    writeln!(report, "## Table 1 — tokens & confidence per exit\n")?;
    writeln!(report, "```\n{}\n```\n", tables::table1(&mut edge, &mut cloud, "the turing test is", 24)?)?;

    eprintln!("recording traces for Tables 2/4 + Fig 4 ({} prompts x 2 datasets x 4 policies)...",
              cfg.n_prompts);
    let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;

    eprintln!("Table 2 (deployment strategies)...");
    writeln!(report, "## Table 2 — cost & performance across deployment strategies\n")?;
    writeln!(report, "```\n{}\n```\n", tables::table2(&rec, dims, link, &cfg))?;

    eprintln!("Table 3 (precision / thresholds)...");
    writeln!(report, "## Table 3 — accuracy across thresholds and precision\n")?;
    writeln!(report, "```\n{}\n```\n", tables::table3(&mut edge, &mut cloud, &cfg)?)?;

    eprintln!("Table 4 (ablation)...");
    writeln!(report, "## Table 4 — ablation study\n")?;
    writeln!(report, "```\n{}\n```\n", tables::table4(&rec, dims, link, &cfg))?;

    eprintln!("Figure 4 (scaling)...");
    writeln!(report, "## Figure 4 — multi-client scaling\n")?;
    writeln!(report, "```\n{}\n```\n", tables::fig4(&rec, dims, link, &cfg, 5))?;

    writeln!(report, "calibrated cost model: {:#?}\n", rec.cost)?;

    std::fs::write(&out_path, &report)?;
    println!("{report}");
    eprintln!("written to {out_path}");
    Ok(())
}
