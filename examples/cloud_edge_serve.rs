//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): a real cloud server and N
//! real edge clients over TCP on a WAN-throttled link, all layers live —
//! PJRT inference on both sides, dual-channel protocol, content manager,
//! async parallel upload.  Reports per-request latency, throughput, and
//! the request-cloud rate.
//!
//!     cargo run --release --example cloud_edge_serve -- [--clients 3]
//!         [--prompts 5] [--threshold 0.8] [--link wifi] [--workers 2]

use std::time::Instant;

use anyhow::Result;

use ce_collm::config::{CloudConfig, DeploymentConfig};
use ce_collm::coordinator::cloud::{CloudServer, SessionFactory};
use ce_collm::coordinator::edge::{CloudLink, EdgeClient};
use ce_collm::eval::datasets::{self, Dataset};
use ce_collm::model::manifest::Manifest;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::net::transport::{TcpTransport, Throttled, Transport};
use ce_collm::runtime::stack::LocalStack;
use ce_collm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let n_clients: usize = args.get_parse("clients", 3);
    let n_prompts: usize = args.get_parse("prompts", 5);
    let threshold: f32 = args.get_parse("threshold", 0.8);
    let workers: usize = args.get_parse("workers", 2);
    let link = LinkProfile::by_name(&args.get_or("link", "wifi")).expect("link profile");
    let artifacts = args.get_or("artifacts", "artifacts");

    let dims = Manifest::load(std::path::Path::new(&artifacts))?.model;
    let art2 = artifacts.clone();
    // the builder runs once per scheduler worker, on that worker's
    // thread; bind() gives the reactor fleet per-shard SO_REUSEPORT
    // listeners on Linux
    let server = CloudServer::bind(
        "127.0.0.1:0",
        dims.clone(),
        CloudConfig::with_workers(workers),
        move || {
            let stack = LocalStack::load(&art2)?;
            let f: SessionFactory = Box::new(move |_| Ok(Box::new(stack.cloud_session()) as _));
            Ok(f)
        },
    )?;
    let addr = server.addr;
    println!(
        "starting cloud server on {addr} (link profile: {}, θ={threshold}, {} workers, \
         {} reactor shards)",
        link.name,
        workers,
        server.shards()
    );

    // Edge clients run on separate threads (separate PJRT stacks, as
    // separate edge devices would).  Requests are batched per client.
    let wall0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.to_string();
        let artifacts = artifacts.clone();
        handles.push(std::thread::spawn(move || -> Result<Report> {
            let stack = LocalStack::load(&artifacts)?;
            let mut cfg = DeploymentConfig::with_threshold(threshold);
            cfg.device_id = c as u64 + 1;
            cfg.max_new_tokens = 48;
            let upload: Box<dyn Transport + Send> =
                Box::new(Throttled::new(TcpTransport::connect(&addr)?, link));
            let infer: Box<dyn Transport> =
                Box::new(Throttled::new(TcpTransport::connect(&addr)?, link));
            let cl = CloudLink::new(cfg.device_id, upload, infer)?;
            let mut client = EdgeClient::with_cloud(stack.edge_session(), cfg, cl);

            let prompts = datasets::generate(Dataset::Alpaca, n_prompts, 1000 + c as u64);
            let mut rep = Report::default();
            for case in &prompts.cases {
                let t0 = Instant::now();
                let out = client.generate(&case.prompt)?;
                rep.latencies_s.push(t0.elapsed().as_secs_f64());
                rep.tokens += out.tokens.len();
                rep.cloud_tokens += out.counters.tokens_cloud;
                rep.bytes_up += out.counters.bytes_up;
            }
            Ok(rep)
        }));
    }

    let mut all = Report::default();
    for h in handles {
        let r = h.join().expect("client thread")?;
        all.merge(r);
    }
    let wall = wall0.elapsed().as_secs_f64();
    let stats = server.shutdown();

    all.latencies_s.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| all.latencies_s[(p * (all.latencies_s.len() - 1) as f64) as usize];
    println!("\n=== end-to-end serve results ===");
    println!("clients: {n_clients}, prompts/client: {n_prompts}, θ={threshold}, link={}", link.name);
    println!(
        "requests: {}   tokens: {}   wall: {wall:.2}s   throughput: {:.1} tok/s",
        all.latencies_s.len(),
        all.tokens,
        all.tokens as f64 / wall
    );
    println!(
        "request latency: p50 {:.3}s  p90 {:.3}s  max {:.3}s",
        pct(0.5),
        pct(0.9),
        all.latencies_s.last().unwrap()
    );
    println!(
        "request-cloud rate: {:.1}%   uploaded: {:.2} MB   cloud GPU busy: {:.2}s over {} requests",
        100.0 * all.cloud_tokens as f64 / all.tokens as f64,
        all.bytes_up as f64 / 1e6,
        stats.busy_s,
        stats.requests_served,
    );
    assert_eq!(stats.active_devices, 0, "content manager must be empty at shutdown");
    println!("content manager: all sessions released ✓");
    Ok(())
}

#[derive(Default)]
struct Report {
    latencies_s: Vec<f64>,
    tokens: usize,
    cloud_tokens: usize,
    bytes_up: u64,
}

impl Report {
    fn merge(&mut self, o: Report) {
        self.latencies_s.extend(o.latencies_s);
        self.tokens += o.tokens;
        self.cloud_tokens += o.cloud_tokens;
        self.bytes_up += o.bytes_up;
    }
}
