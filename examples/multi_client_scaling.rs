//! Figure 4 (a)(b) driver: scalability from 1 to 5 edge devices, CE-CoLLM
//! (θ ∈ {0.8, 0.9}) vs the cloud-based deployment.
//!
//!     cargo run --release --example multi_client_scaling -- [--clients 5]
//!         [--prompts 15] [--link paper]

use anyhow::Result;

use ce_collm::harness::runner::{record_main_experiments, ExperimentConfig};
use ce_collm::harness::tables;
use ce_collm::net::profiles::LinkProfile;
use ce_collm::runtime::stack::LocalStack;
use ce_collm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let stack = LocalStack::load(args.get_or("artifacts", "artifacts"))?;
    let cfg = ExperimentConfig {
        n_prompts: args.get_parse("prompts", 15),
        repeats: args.get_parse("repeats", 3),
        max_new_tokens: args.get_parse("max-new", 64),
        seed: args.get_parse("seed", 42),
    };
    let link = LinkProfile::by_name(&args.get_or("link", "paper")).expect("link profile");

    println!("recording traces ({} prompts per dataset, real engines)...", cfg.n_prompts);
    let mut edge = stack.edge_session();
    let mut cloud = stack.cloud_session();
    let rec = record_main_experiments(&mut edge, &mut cloud, &cfg)?;

    println!("\n{}", tables::fig4(&rec, &stack.manifest.model, link, &cfg,
                                  args.get_parse("clients", 5)));
    Ok(())
}
