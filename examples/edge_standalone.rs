//! Edge standalone mode (paper §4.1 "Low-Latency Mode"): the edge LLM
//! partition answers every token locally — the last early exit becomes
//! the output layer.  Reports per-token latency percentiles, the numbers
//! behind the paper's low-latency claim.
//!
//!     cargo run --release --example edge_standalone

use anyhow::Result;

use ce_collm::config::DeploymentConfig;
use ce_collm::coordinator::edge::EdgeClient;
use ce_collm::eval::datasets::{self, Dataset};
use ce_collm::runtime::stack::LocalStack;

fn main() -> Result<()> {
    let stack = LocalStack::load("artifacts")?;
    let mut cfg = DeploymentConfig::standalone();
    cfg.max_new_tokens = 48;
    let mut client = EdgeClient::standalone(stack.edge_session(), cfg);

    let prompts = datasets::generate(Dataset::Alpaca, 10, 7);
    let mut per_token_ms: Vec<f64> = Vec::new();
    let mut exit1 = 0usize;
    let mut total_tokens = 0usize;

    println!("edge standalone inference over {} prompts:\n", prompts.cases.len());
    for case in &prompts.cases {
        let out = client.generate(&case.prompt)?;
        per_token_ms.push(1000.0 * out.cost.edge_s / out.tokens.len().max(1) as f64);
        exit1 += out.counters.tokens_exit1;
        total_tokens += out.tokens.len();
        println!("  '{}' → '{}'", case.prompt, out.text.trim_end());
        assert_eq!(out.counters.cloud_requests, 0, "standalone must never call the cloud");
    }

    per_token_ms.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| per_token_ms[(p * (per_token_ms.len() - 1) as f64) as usize];
    println!(
        "\nper-token edge latency: p50 {:.2} ms, p90 {:.2} ms, max {:.2} ms",
        pct(0.5),
        pct(0.9),
        per_token_ms.last().unwrap()
    );
    println!(
        "{}/{} tokens exited at exit-1 (skipped {} deeper layers each)",
        exit1,
        total_tokens,
        stack.manifest.model.l_ee2 - stack.manifest.model.l_ee1
    );
    println!("cloud requests: 0; bytes transmitted: 0  — full privacy isolation");
    Ok(())
}
