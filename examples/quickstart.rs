//! Quickstart: load the AOT artifacts and generate text in both of
//! CE-CoLLM's modes — edge standalone (low latency) and cloud-edge
//! collaborative (high accuracy) — entirely in-process.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use ce_collm::config::ExitPolicy;
use ce_collm::coordinator::policy::ExitPoint;
use ce_collm::harness::trace::{record, CallTimings};
use ce_collm::quant::Precision;
use ce_collm::runtime::stack::LocalStack;

fn main() -> Result<()> {
    let stack = LocalStack::load("artifacts")?;
    println!(
        "loaded CE-CoLLM stack: {} layers, exits after layers {} and {}, vocab {}",
        stack.manifest.model.n_layers,
        stack.manifest.model.l_ee1,
        stack.manifest.model.l_ee2,
        stack.manifest.model.vocab_size,
    );

    let mut edge = stack.edge_session();
    let mut cloud = stack.cloud_session();
    let prompt = "the machine is a";

    for (label, policy) in [
        ("standalone (low-latency)", ExitPolicy::Standalone { threshold: 0.8 }),
        ("collaborative θ=0.8", ExitPolicy::Threshold(0.8)),
        ("collaborative θ=0.9", ExitPolicy::Threshold(0.9)),
        ("cloud-equivalent θ=1.0", ExitPolicy::Threshold(1.0)),
    ] {
        let mut timings = CallTimings::default();
        let t0 = std::time::Instant::now();
        let tr = record(&mut edge, &mut cloud, policy, Precision::F16, prompt, 48, &mut timings)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "\n[{label}]\n  '{prompt}' → '{}'\n  {} tokens in {:.3}s ({:.1} ms/token); \
             exits: {} @exit1, {} @exit2, {} @cloud",
            tr.text.trim_end(),
            tr.tokens.len(),
            dt,
            1000.0 * dt / tr.tokens.len() as f64,
            tr.count(ExitPoint::Exit1),
            tr.count(ExitPoint::Exit2),
            tr.count(ExitPoint::Cloud),
        );
    }
    Ok(())
}
